//! # dlroofline
//!
//! Reproduction of *"Applying the Roofline Model for Deep Learning
//! performance optimizations"* (Czaja et al., CS.DC 2020) as a
//! Rust + JAX + Pallas three-layer system.
//!
//! The crate provides:
//!
//! * a **NUMA platform simulator** ([`sim`]) — cores with a ported issue
//!   model, a set-associative cache hierarchy with hardware/software
//!   prefetchers, DDR channels behind per-socket integrated memory
//!   controllers (IMC), and a two-node NUMA topology with first-touch
//!   allocation and pressure-driven migration;
//! * a **PMU subsystem** ([`pmu`]) modelling the
//!   `FP_ARITH_INST_RETIRED.*` counter family (FMA retires count double)
//!   and the IMC uncore counters, with the paper's two-run
//!   overhead-subtraction measurement protocol;
//! * **host microbenchmarks** ([`hostbench`]) — runtime-generated FMA
//!   assembly (a tiny JIT, the paper used Xbyak) and
//!   memset/memcpy/non-temporal-store bandwidth probes with thread
//!   affinity control;
//! * **analytic kernel models** ([`kernels`]) of the oneDNN primitives the
//!   paper evaluates (direct & Winograd convolution, inner product,
//!   average pooling, GELU, layer normalisation) in NCHW and blocked
//!   NCHW16C layouts;
//! * the **roofline model** itself ([`roofline`]) with ASCII/SVG plots and
//!   paper-style reports;
//! * a **measurement harness** ([`harness`]) — cold/warm cache protocols,
//!   data-driven execution scenarios (the paper's three plus
//!   interleaved / remote-only / half-socket presets), and a declarative
//!   experiment spec registry that replaces per-figure code with data;
//! * a **PJRT runtime** ([`runtime`]) that loads the AOT-compiled JAX /
//!   Pallas artifacts (`artifacts/*.hlo.txt`) and executes them from Rust —
//!   Python never runs on the measurement path;
//! * a **coordinator** ([`coordinator`]) tying it all together behind the
//!   `dlroofline` CLI — including a parallel, memoizing plan executor
//!   (`sweep --jobs N`), a persistent content-addressed cell cache
//!   (`--cache-dir`, [`coordinator::store`]) that makes repeated sweeps
//!   incremental, and versioned `run.json` manifests that make every
//!   run a reproducible artifact;
//! * a **tuning subsystem** ([`tune`]) — `dlroofline tune` expands
//!   kernel tuning knobs (blocking, loop order, layout, SW prefetch)
//!   into a variant lattice, drives it through the cached plan executor
//!   (warm re-tunes simulate nothing) and ranks variants per scenario
//!   by attainable FLOP/s with a binding-level explanation per winner;
//! * a **sweep service** ([`serve`]) — `dlroofline serve` runs the plan
//!   executor behind a line-delimited JSON TCP protocol, sharding cell
//!   simulation across workers that coordinate purely through claim
//!   files in the shared cell store (so several daemons can split one
//!   sweep), with served results byte-identical to a direct `sweep`;
//! * **run artifacts** ([`artifact`]) — `dlroofline pack`/`unpack`
//!   bundle a run directory plus its store records into a checksummed
//!   deterministic tarball that another host can verify and use to seed
//!   its own cache;
//! * a **differential fuzzer** ([`fuzz`]) — `dlroofline fuzz` feeds
//!   seeded arbitrary traces, degenerate cache geometries, kernel specs
//!   and scenarios through all three sim engines and the serialization
//!   surfaces, shrinking any divergence to a replayable corpus file
//!   (`dlroofline fuzz replay`).
//!
//! See `README.md` for the documentation map, `docs/` for the book
//! (architecture overview, CLI reference, on-disk formats) and
//! `DESIGN.md` for the architectural decisions; each generated report
//! carries its own paper-vs-measured table.

// Every public item carries documentation; the CI docs job promotes
// rustdoc warnings (including missing docs and broken intra-doc links)
// to errors.
#![warn(missing_docs)]

pub mod artifact;
pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod fuzz;
pub mod harness;
pub mod hostbench;
pub mod kernels;
pub mod pmu;
pub mod roofline;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod testutil;
pub mod tune;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
