//! Greedy case minimization.
//!
//! When a case fails, the fuzzer does not write the raw (often noisy)
//! case to the corpus — it first shrinks it: propose structurally
//! smaller variants (fewer threads, fewer runs, shorter runs, simpler
//! geometry, smaller kernel dims), keep any variant that still fails,
//! and restart from it. The loop is greedy with restart, so the result
//! is a local minimum: removing any single remaining element makes the
//! failure disappear. A bounded check-evaluation budget keeps shrinking
//! of expensive kernel cases affordable.

use crate::fuzz::gen::{FaultsCase, FuzzCase, KernelCase, KernelFamily, RoundtripCase, TraceCase};
use crate::fuzz::gen::trace::NodeMap;
use crate::harness::cache_state::CacheState;
use crate::harness::scenario::PlacementSpec;
use crate::sim::numa::MemPolicy;
use crate::sim::trace::AccessKind;
use crate::util::json::Json;

/// Outcome of a shrink session.
#[derive(Clone, Debug)]
pub struct ShrinkResult {
    /// The minimized case (equal to the input if nothing shrank).
    pub case: FuzzCase,
    /// The failure message of the minimized case.
    pub failure: String,
    /// Accepted shrink steps.
    pub steps: usize,
    /// Check evaluations spent.
    pub attempts: usize,
}

/// Greedily minimize `case` while `check` keeps failing. `max_attempts`
/// bounds the number of check evaluations.
pub fn minimize(
    case: &FuzzCase,
    failure: String,
    check: &mut dyn FnMut(&FuzzCase) -> Option<String>,
    max_attempts: usize,
) -> ShrinkResult {
    let mut best = case.clone();
    let mut best_failure = failure;
    let mut steps = 0;
    let mut attempts = 0;
    'outer: loop {
        for candidate in candidates(&best) {
            if candidate == best {
                continue;
            }
            if attempts >= max_attempts {
                break 'outer;
            }
            attempts += 1;
            if let Some(msg) = check(&candidate) {
                best = candidate;
                best_failure = msg;
                steps += 1;
                continue 'outer; // restart from the smaller case
            }
        }
        break; // full pass with no accepted shrink: local minimum
    }
    ShrinkResult { case: best, failure: best_failure, steps, attempts }
}

/// Structurally smaller variants of `case`, most aggressive first.
pub fn candidates(case: &FuzzCase) -> Vec<FuzzCase> {
    match case {
        FuzzCase::Trace(c) => trace_candidates(c).into_iter().map(FuzzCase::Trace).collect(),
        FuzzCase::Kernel(c) => kernel_candidates(c).into_iter().map(FuzzCase::Kernel).collect(),
        FuzzCase::Roundtrip(c) => {
            roundtrip_candidates(c).into_iter().map(FuzzCase::Roundtrip).collect()
        }
        FuzzCase::Faults(c) => faults_candidates(c).into_iter().map(FuzzCase::Faults).collect(),
    }
}

fn trace_candidates(case: &TraceCase) -> Vec<TraceCase> {
    let mut out = Vec::new();
    let mut push = |mut c: TraceCase| {
        c.sanitize();
        out.push(c);
    };

    // Whole threads first — the biggest single cut.
    if case.threads() > 1 {
        for i in 0..case.threads() {
            let mut c = case.clone();
            c.runs.remove(i);
            c.thread_nodes.remove(i);
            push(c);
        }
    }
    // Then whole runs.
    for t in 0..case.threads() {
        if case.runs[t].len() > 1 {
            for j in 0..case.runs[t].len() {
                let mut c = case.clone();
                c.runs[t].remove(j);
                push(c);
            }
        }
    }
    if case.rounds > 1 {
        let mut c = case.clone();
        c.rounds = 1;
        push(c);
    }
    // Per-run simplifications.
    for t in 0..case.threads() {
        for j in 0..case.runs[t].len() {
            let r = case.runs[t][j];
            if r.count > 1 {
                let mut c = case.clone();
                c.runs[t][j].count = r.count / 2;
                push(c);
            }
            if r.stride != 0 {
                let mut c = case.clone();
                c.runs[t][j].stride = 0;
                push(c);
                if r.stride != 64 {
                    let mut c = case.clone();
                    c.runs[t][j].stride = 64;
                    push(c);
                }
            }
            if r.kind != AccessKind::Load {
                let mut c = case.clone();
                c.runs[t][j].kind = AccessKind::Load;
                push(c);
            }
            if r.size != 64 {
                let mut c = case.clone();
                c.runs[t][j].size = 64;
                push(c);
            }
            if r.base != 0 && r.stride >= 0 {
                let mut c = case.clone();
                c.runs[t][j].base = 0;
                push(c);
            }
        }
    }
    // Geometry simplifications.
    if case.geometry.prefetch {
        let mut c = case.clone();
        c.geometry.prefetch = false;
        push(c);
    }
    for pick in 0..6usize {
        let mut c = case.clone();
        let g = &mut c.geometry;
        let field = match pick {
            0 => &mut g.l1_ways,
            1 => &mut g.l2_ways,
            2 => &mut g.llc_ways,
            3 => &mut g.l1_sets,
            4 => &mut g.l2_sets,
            _ => &mut g.llc_sets,
        };
        if *field > 1 {
            *field = 1;
            push(c);
        }
    }
    // NUMA simplifications last: they often mask placement bugs.
    if case.nodes > 1 {
        let mut c = case.clone();
        c.nodes = 1;
        c.node_map = NodeMap::Zero;
        for n in &mut c.thread_nodes {
            *n = 0;
        }
        push(c);
    }
    if case.node_map != NodeMap::Zero {
        let mut c = case.clone();
        c.node_map = NodeMap::Zero;
        push(c);
    }
    out
}

fn kernel_candidates(case: &KernelCase) -> Vec<KernelCase> {
    let mut out = Vec::new();
    let mut push = |mut c: KernelCase| {
        c.sanitize();
        out.push(c);
    };

    // Halve each kernel dimension independently.
    let dims: Vec<KernelFamily> = match case.family {
        KernelFamily::Reduction { n } => vec![KernelFamily::Reduction { n: n / 2 }],
        KernelFamily::InnerProduct { m, k, n } => vec![
            KernelFamily::InnerProduct { m: m / 2, k, n },
            KernelFamily::InnerProduct { m, k: k / 2, n },
            KernelFamily::InnerProduct { m, k, n: n / 2 },
        ],
        KernelFamily::Gelu { n, c, h, w } => vec![
            KernelFamily::Gelu { n, c: c / 2, h, w },
            KernelFamily::Gelu { n, c, h: h / 2, w },
            KernelFamily::Gelu { n, c, h, w: w / 2 },
        ],
        KernelFamily::LayerNorm { rows, hidden } => vec![
            KernelFamily::LayerNorm { rows: rows / 2, hidden },
            KernelFamily::LayerNorm { rows, hidden: hidden / 2 },
        ],
        KernelFamily::AvgPool { c, ih, iw, kernel, stride } => vec![
            KernelFamily::AvgPool { c: c / 2, ih, iw, kernel, stride },
            KernelFamily::AvgPool { c, ih: ih / 2, iw, kernel, stride },
            KernelFamily::AvgPool { c, ih, iw: iw / 2, kernel, stride },
        ],
    };
    for family in dims {
        let mut c = *case;
        c.family = family;
        push(c);
    }
    if case.scenario.threads > 1 {
        let mut c = *case;
        c.scenario.threads /= 2;
        push(c);
        let mut c = *case;
        c.scenario.threads = 1;
        push(c);
    }
    if case.scenario.cache == CacheState::Warm {
        let mut c = *case;
        c.scenario.cache = CacheState::Cold;
        push(c);
    }
    if case.scenario.placement != PlacementSpec::Bind(0) {
        let mut c = *case;
        c.scenario.placement = PlacementSpec::Bind(0);
        push(c);
    }
    if case.scenario.mem != MemPolicy::BindNode(0) {
        let mut c = *case;
        c.scenario.mem = MemPolicy::BindNode(0);
        push(c);
    }
    out
}

fn roundtrip_candidates(case: &RoundtripCase) -> Vec<RoundtripCase> {
    let mut out = Vec::new();
    match case {
        RoundtripCase::Tar { entries } => {
            if entries.len() > 1 {
                for i in 0..entries.len() {
                    let mut e = entries.clone();
                    e.remove(i);
                    out.push(RoundtripCase::Tar { entries: e });
                }
            }
            for i in 0..entries.len() {
                let hex = &entries[i].1;
                if !hex.is_empty() {
                    let mut e = entries.clone();
                    let half = (hex.len() / 4) * 2; // even prefix, half the bytes
                    e[i].1 = hex[..half].to_string();
                    out.push(RoundtripCase::Tar { entries: e });
                }
            }
        }
        RoundtripCase::Protocol { .. } => {} // atomic: one wire line
        RoundtripCase::Manifest { doc } => {
            // Shrink structurally through the manifest model; if the doc
            // does not even parse, that is the minimal failure already.
            use crate::coordinator::manifest::RunManifest;
            let Ok(parsed) = Json::parse(doc) else { return out };
            let Ok(manifest) = RunManifest::from_json(&parsed) else { return out };
            if !manifest.cells.is_empty() {
                let mut m = manifest.clone();
                m.cells.clear();
                out.push(RoundtripCase::Manifest { doc: m.to_string_pretty() });
                for i in 0..manifest.cells.len() {
                    let mut m = manifest.clone();
                    m.cells.remove(i);
                    out.push(RoundtripCase::Manifest { doc: m.to_string_pretty() });
                    if manifest.cells[i].levels.is_some() {
                        let mut m = manifest.clone();
                        m.cells[i].levels = None;
                        out.push(RoundtripCase::Manifest { doc: m.to_string_pretty() });
                    }
                }
            }
            if !manifest.files.is_empty() {
                let mut m = manifest.clone();
                m.files.clear();
                out.push(RoundtripCase::Manifest { doc: m.to_string_pretty() });
            }
        }
    }
    out
}

fn faults_candidates(case: &FaultsCase) -> Vec<FaultsCase> {
    // The plan seed is atomic (it *is* the fault schedule); shrink the
    // workload around it: fewer keys, fewer files, shorter bodies.
    let mut out = Vec::new();
    if case.keys.len() > 1 {
        for i in 0..case.keys.len() {
            let mut c = case.clone();
            c.keys.remove(i);
            out.push(c);
        }
    }
    if case.files.len() > 1 {
        for i in 0..case.files.len() {
            let mut c = case.clone();
            c.files.remove(i);
            out.push(c);
        }
    }
    for i in 0..case.files.len() {
        let body = &case.files[i].1;
        if !body.is_empty() {
            let mut c = case.clone();
            let mut half = body.len() / 2;
            while !body.is_char_boundary(half) {
                half -= 1;
            }
            c.files[i].1 = body[..half].to_string();
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    /// A synthetic "bug" that fires whenever any run has count ≥ 4:
    /// shrinking must converge to one thread × one run × count ∈ [4, 7]
    /// (halving from below 8 lands in that window, and one more halving
    /// would drop below 4 and pass).
    fn synthetic_check(case: &FuzzCase) -> Option<String> {
        match case {
            FuzzCase::Trace(c) => c
                .runs
                .iter()
                .flatten()
                .any(|r| r.count >= 4)
                .then(|| "synthetic divergence".to_string()),
            _ => None,
        }
    }

    #[test]
    fn shrinks_synthetic_trace_failure_to_local_minimum() {
        let mut rng = Prng::new(42);
        let mut shrunk_any = false;
        for _ in 0..32 {
            let case = FuzzCase::Trace(TraceCase::generate(&mut rng));
            let Some(failure) = synthetic_check(&case) else { continue };
            let mut check = synthetic_check;
            let result =
                minimize(&case, failure, &mut |c| check(c), 2000);
            let FuzzCase::Trace(min) = &result.case else { panic!("kind changed") };
            // Still failing, and minimal: one thread, one run, count in
            // the smallest still-failing window, everything else inert.
            assert!(synthetic_check(&result.case).is_some());
            assert_eq!(min.threads(), 1);
            assert_eq!(min.runs[0].len(), 1);
            let r = min.runs[0][0];
            assert!((4..8).contains(&r.count), "count {} not minimal", r.count);
            assert_eq!(r.stride, 0);
            assert_eq!(r.kind, AccessKind::Load);
            assert_eq!(min.rounds, 1);
            assert_eq!(min.nodes, 1);
            shrunk_any = result.steps > 0 || shrunk_any;
        }
        assert!(shrunk_any, "no generated case ever exercised the shrinker");
    }

    #[test]
    fn kernel_candidates_stay_valid_and_smaller() {
        let mut rng = Prng::new(5);
        let config = crate::sim::machine::MachineConfig::xeon_6248();
        for _ in 0..64 {
            let case = KernelCase::generate(&mut rng);
            for cand in kernel_candidates(&case) {
                cand.scenario.spec().validate(&config).unwrap();
            }
        }
    }

    #[test]
    fn passing_case_shrinks_to_itself() {
        let case = FuzzCase::Trace(TraceCase::generate(&mut Prng::new(1)));
        let result = minimize(&case, "msg".into(), &mut |_| None, 100);
        assert_eq!(result.case, case);
        assert_eq!(result.steps, 0);
    }
}
