//! Replayable corpus files.
//!
//! A corpus file records one failing case in its *concrete* form — the
//! exact geometry, trace, kernel spec, or document that diverged — plus
//! the seed that produced it and the failure message. Replaying
//! (`dlroofline fuzz replay <file>`) deserializes the case and re-runs
//! the same check the fuzz loop used; it does not re-generate from the
//! seed, so corpus files keep reproducing even after the generators
//! evolve.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::fuzz::gen::FuzzCase;
use crate::util::hash::hex64;
use crate::util::json::Json;

/// Corpus file schema version.
pub const CORPUS_SCHEMA_VERSION: u64 = 1;

/// One failing case, as written to / read from the corpus directory.
#[derive(Clone, Debug)]
pub struct CorpusFile {
    /// The per-case seed that generated the (pre-shrink) failure.
    pub seed: u64,
    /// The minimized failing case, in concrete form.
    pub case: FuzzCase,
    /// The divergence message observed when the case was written.
    pub failure: String,
}

impl CorpusFile {
    /// Serialize to the corpus document form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::num(CORPUS_SCHEMA_VERSION as f64)),
            ("kind", Json::str(self.case.kind())),
            // u64 seeds don't fit f64 exactly; store as a decimal string.
            ("seed", Json::str(self.seed.to_string())),
            ("case", self.case.to_json()),
            ("failure", Json::str(self.failure.as_str())),
        ])
    }

    /// Parse a corpus document.
    pub fn from_json(v: &Json) -> Result<CorpusFile> {
        let version = v.expect("schema_version")?.as_f64()?;
        if version != CORPUS_SCHEMA_VERSION as f64 {
            bail!("unsupported corpus schema version {version}");
        }
        let kind = v.expect("kind")?.as_str()?;
        let seed: u64 = v
            .expect("seed")?
            .as_str()?
            .parse()
            .context("corpus 'seed' must be a decimal u64 string")?;
        Ok(CorpusFile {
            seed,
            case: FuzzCase::from_json(kind, v.expect("case")?)?,
            failure: v.expect("failure")?.as_str()?.to_string(),
        })
    }

    /// File name this case is stored under: `fuzz-<kind>-<seed hex>.json`.
    pub fn file_name(&self) -> String {
        format!("fuzz-{}-{}.json", self.case.kind(), hex64(self.seed))
    }

    /// Write into `dir` (created if missing); returns the file path.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating corpus dir {}", dir.display()))?;
        let path = dir.join(self.file_name());
        fs::write(&path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing corpus file {}", path.display()))?;
        Ok(path)
    }

    /// Load a corpus file from disk.
    pub fn load(path: &Path) -> Result<CorpusFile> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading corpus file {}", path.display()))?;
        let doc = Json::parse(&text)
            .with_context(|| format!("parsing corpus file {}", path.display()))?;
        Self::from_json(&doc)
            .with_context(|| format!("decoding corpus file {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;
    use crate::util::prng::Prng;

    #[test]
    fn corpus_files_roundtrip_through_disk() {
        let dir = TempDir::new("fuzz-corpus");
        let mut rng = Prng::new(3);
        for _ in 0..8 {
            let case = FuzzCase::generate(rng.next_u64());
            let file = CorpusFile {
                seed: rng.next_u64(),
                case: case.clone(),
                failure: "stats diverged: l1 hits 3 vs 4".into(),
            };
            let path = file.write(dir.path()).unwrap();
            assert!(path.file_name().unwrap().to_str().unwrap().starts_with("fuzz-"));
            let back = CorpusFile::load(&path).unwrap();
            assert_eq!(back.case, case);
            assert_eq!(back.seed, file.seed);
            assert_eq!(back.failure, file.failure);
        }
    }

    #[test]
    fn rejects_future_schema_and_bad_seed() {
        let file = CorpusFile {
            seed: u64::MAX, // deliberately above 2^53: must survive exactly
            case: FuzzCase::generate(1),
            failure: "x".into(),
        };
        let doc = file.to_json();
        let back = CorpusFile::from_json(&doc).unwrap();
        assert_eq!(back.seed, u64::MAX);

        let mut obj = match doc {
            Json::Obj(map) => map,
            _ => unreachable!(),
        };
        obj.insert("schema_version".into(), Json::num(99.0));
        assert!(CorpusFile::from_json(&Json::Obj(obj)).is_err());
    }
}
