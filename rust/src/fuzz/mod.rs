//! Deterministic differential property fuzzer (`dlroofline fuzz`).
//!
//! The four simulation engines — scalar reference, batched SoA,
//! two-phase parallel, set-sharded parallel — are pinned bit-identical
//! by example-based parity tests (`tests/sim_parity.rs`). This module
//! hardens that
//! contract with *randomized* differential testing: seeded generators
//! ([`gen`]) draw arbitrary access traces, cache geometries (including
//! degenerate shapes the presets never build), kernel specs, scenarios
//! beyond the six presets, and worker counts; the drivers here run each
//! case through all engines and demand identical [`TrafficStats`],
//! FP counters, and serialized measurements, plus exact round-trips for
//! every serialization surface (manifest v1/v2, cell-store records,
//! ustar artifacts, serve protocol lines). A fourth kind replays seeded
//! fault schedules ([`gen::FaultsCase`]) against the crash-safety
//! surfaces — atomic writes, the cell store, claim publishing — with
//! *graceful degradation* as the oracle: every faulted operation must
//! either fail with a clean error or leave state indistinguishable from
//! a slower fault-free run.
//!
//! Everything is deterministic: `fuzz --seed S --cases N` derives one
//! per-case seed stream from `S` (xoshiro256**, `util/prng.rs` — no
//! cargo-fuzz, no nightly), so a session replays exactly and the
//! summary digest can be compared across runs and machines. Failing
//! cases are shrunk by greedy minimization ([`shrink`]) and written as
//! replayable JSON corpus files ([`corpus`]); `fuzz replay <file>`
//! re-runs the recorded concrete case. The design generalizes the
//! no-shrinking sketch in [`testutil::prop`](crate::testutil::prop) to
//! a full generate/check/shrink/replay loop.

pub mod corpus;
pub mod gen;
pub mod shrink;

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::artifact::tar::{read_tar, write_tar};
use crate::coordinator::manifest::RunManifest;
use crate::coordinator::store::{CellStore, Lookup};
use crate::fuzz::corpus::CorpusFile;
use crate::fuzz::gen::{bytes_from_hex, FaultsCase, FuzzCase, KernelCase, RoundtripCase, TraceCase};
use crate::harness::measure::{
    measure_kernel, measure_kernel_parallel, measure_kernel_reference, measure_kernel_sharded,
    KernelMeasurement,
};
use crate::serve::claims::{ClaimOutcome, ClaimSet};
use crate::serve::protocol::Request;
use crate::sim::hierarchy::{MemorySystem, TrafficStats};
use crate::sim::machine::{Machine, MachineConfig};
use crate::sim::numa::Placement;
use crate::testutil::TempDir;
use crate::util::fsutil::{read_to_string_io_with, write_atomic_unique_with, FaultInjector};
use crate::util::hash::fnv1a_64;
use crate::util::json::Json;
use crate::util::prng::Prng;

/// Two-phase worker counts every differential case is exercised at
/// (serial, minimal parallelism, more workers than generated threads).
pub const WORKER_COUNTS: [usize; 3] = [1, 2, 8];

/// Set-shard counts the sharded engine is exercised at, crossed with
/// [`WORKER_COUNTS`]: the serial-degenerate count, the minimal split,
/// and a prime that never divides the generated set counts evenly (so
/// the last shard group is a different size than the rest).
pub const SHARD_COUNTS: [usize; 3] = [1, 2, 7];

/// Shrink budget (check evaluations) for cheap case kinds. Trace
/// checks cost milliseconds and shrink candidates get cheaper as the
/// case shrinks, so the minimizer can afford a generous probe count.
const SHRINK_BUDGET: usize = 2000;
/// Shrink budget for kernel cases — each check runs the measurement
/// pipeline fourteen times (reference, batched, 3 two-phase, 9
/// sharded), so the minimizer gets far fewer probes.
const SHRINK_BUDGET_KERNEL: usize = 60;

/// A fuzz session's parameters.
#[derive(Clone, Debug)]
pub struct FuzzConfig {
    /// Session seed; the per-case seed stream derives from it.
    pub seed: u64,
    /// Cases to execute.
    pub cases: usize,
    /// Wall-clock budget in minutes (0 disables the budget). The seed →
    /// case mapping is unaffected; the budget only truncates the run.
    pub minutes: f64,
    /// Directory failing cases are written to.
    pub corpus_dir: PathBuf,
    /// Restrict the session to one case kind
    /// (`trace|kernel|roundtrip|faults`); `None` draws the weighted mix.
    pub only: Option<String>,
}

/// One failing (shrunk, corpus-written) case.
#[derive(Clone, Debug)]
pub struct FuzzFailure {
    /// Zero-based index in the session's case stream.
    pub index: usize,
    /// The per-case seed that produced the failure.
    pub case_seed: u64,
    /// Case kind label.
    pub kind: &'static str,
    /// Divergence message of the minimized case.
    pub failure: String,
    /// Accepted shrink steps.
    pub shrink_steps: usize,
    /// Where the replayable corpus file was written.
    pub corpus_path: PathBuf,
}

/// Summary of a fuzz session.
#[derive(Clone, Debug, Default)]
pub struct FuzzOutcome {
    /// Cases actually executed.
    pub executed: usize,
    /// Trace-differential cases among them.
    pub trace_cases: usize,
    /// Measurement-differential cases among them.
    pub kernel_cases: usize,
    /// Serialization round-trip cases among them.
    pub roundtrip_cases: usize,
    /// Fault-injection cases among them.
    pub faults_cases: usize,
    /// Order-sensitive FNV-1a digest over every executed case and its
    /// verdict — two runs with the same seed and case count must print
    /// the same digest (CI's determinism check compares exactly this).
    pub digest: u64,
    /// The wall-clock budget stopped the session before `cases` ran.
    pub truncated: bool,
    /// The first failure, if any (the session stops at it).
    pub failure: Option<FuzzFailure>,
}

/// Run a fuzz session with the shipped differential checks.
pub fn run_fuzz(config: &FuzzConfig, progress: &mut dyn FnMut(String)) -> Result<FuzzOutcome> {
    run_fuzz_with(config, &mut check_case, progress)
}

/// As [`run_fuzz`], generic over the check — lets tests drive the whole
/// generate/shrink/corpus pipeline against a deliberately broken check
/// without patching an engine.
pub fn run_fuzz_with(
    config: &FuzzConfig,
    check: &mut dyn FnMut(&FuzzCase) -> Option<String>,
    progress: &mut dyn FnMut(String),
) -> Result<FuzzOutcome> {
    let start = Instant::now();
    if let Some(kind) = config.only.as_deref() {
        if !matches!(kind, "trace" | "kernel" | "roundtrip" | "faults") {
            bail!("unknown fuzz case kind '{kind}' (trace|kernel|roundtrip|faults)");
        }
    }
    let budget =
        (config.minutes > 0.0).then(|| Duration::from_secs_f64(config.minutes * 60.0));
    let mut session = Prng::new(config.seed);
    let mut outcome = FuzzOutcome { digest: config.seed, ..Default::default() };

    for index in 0..config.cases {
        let case_seed = session.next_u64();
        if let Some(b) = budget {
            if start.elapsed() >= b {
                outcome.truncated = true;
                progress(format!(
                    "wall-clock budget hit after {} of {} cases",
                    outcome.executed, config.cases
                ));
                break;
            }
        }
        let case = match config.only.as_deref() {
            Some(kind) => FuzzCase::generate_only(kind, case_seed)?,
            None => FuzzCase::generate(case_seed),
        };
        match &case {
            FuzzCase::Trace(_) => outcome.trace_cases += 1,
            FuzzCase::Kernel(_) => outcome.kernel_cases += 1,
            FuzzCase::Roundtrip(_) => outcome.roundtrip_cases += 1,
            FuzzCase::Faults(_) => outcome.faults_cases += 1,
        }
        let verdict = check(&case);
        outcome.executed += 1;
        outcome.digest = chain_digest(
            outcome.digest,
            case.kind(),
            &case.to_json().to_string_compact(),
            verdict.as_deref(),
        );
        if let Some(msg) = verdict {
            progress(format!(
                "case #{index} ({} seed {case_seed}) diverged: {msg}",
                case.kind()
            ));
            let shrink_budget = match &case {
                FuzzCase::Kernel(_) => SHRINK_BUDGET_KERNEL,
                _ => SHRINK_BUDGET,
            };
            progress(format!("shrinking (budget {shrink_budget} checks)..."));
            let result = shrink::minimize(&case, msg, check, shrink_budget);
            let file = CorpusFile {
                seed: case_seed,
                case: result.case,
                failure: result.failure.clone(),
            };
            let corpus_path = file.write(&config.corpus_dir)?;
            progress(format!(
                "minimized in {} steps ({} checks); wrote {}",
                result.steps,
                result.attempts,
                corpus_path.display()
            ));
            outcome.failure = Some(FuzzFailure {
                index,
                case_seed,
                kind: file.case.kind(),
                failure: result.failure,
                shrink_steps: result.steps,
                corpus_path,
            });
            break;
        }
        if (index + 1) % 100 == 0 {
            progress(format!("{} cases, 0 divergences", index + 1));
        }
    }
    Ok(outcome)
}

/// Replay one corpus file: re-run its recorded concrete case through
/// the shipped checks. Returns the corpus file and the fresh verdict
/// (`None` = the divergence no longer reproduces).
pub fn replay(path: &Path) -> Result<(CorpusFile, Option<String>)> {
    let file = CorpusFile::load(path)?;
    let verdict = check_case(&file.case);
    Ok((file, verdict))
}

/// Run one case through the appropriate differential / round-trip
/// check. `None` means the case passed; `Some(msg)` describes the first
/// divergence.
pub fn check_case(case: &FuzzCase) -> Option<String> {
    match case {
        FuzzCase::Trace(c) => check_trace(c),
        FuzzCase::Kernel(c) => check_kernel(c),
        FuzzCase::Roundtrip(c) => check_roundtrip(c),
        FuzzCase::Faults(c) => check_faults(c),
    }
}

/// Chain one case record into the session digest.
fn chain_digest(digest: u64, kind: &str, case_json: &str, verdict: Option<&str>) -> u64 {
    let record = format!(
        "{:016x}\n{kind}\n{case_json}\n{}",
        digest,
        verdict.unwrap_or("ok")
    );
    fnv1a_64(record.as_bytes())
}

// --------------------------------------------------------------------
// Trace differential
// --------------------------------------------------------------------

/// Run every engine over the case's traces and compare per-round stats
/// against the scalar reference.
fn check_trace(case: &TraceCase) -> Option<String> {
    let traces = case.traces();
    let placement = Placement { thread_nodes: case.thread_nodes.clone(), pinned: true };
    let nodes = case.nodes;
    let map = case.node_map;

    // Each engine gets a fresh memory system; rounds > 1 replay the
    // same traces against retained (warm) cache state.
    let rounds_for = |engine: &mut dyn FnMut(
        &mut MemorySystem,
        &mut dyn FnMut(u64, usize) -> usize,
    ) -> TrafficStats|
     -> Vec<TrafficStats> {
        let mut ms = MemorySystem::new(case.geometry.hierarchy(), nodes, traces.len());
        (0..case.rounds)
            .map(|_| {
                let mut node_of =
                    |addr: u64, toucher: usize| map.node_of(nodes, addr, toucher);
                engine(&mut ms, &mut node_of)
            })
            .collect()
    };

    let reference =
        rounds_for(&mut |ms, node_of| ms.run_reference(&traces, &placement, node_of));
    let compare = |label: &str, got: &[TrafficStats]| -> Option<String> {
        for (round, (want, got)) in reference.iter().zip(got).enumerate() {
            if let Some(d) = want.divergence(got) {
                return Some(format!("{label} vs reference, round {}: {d}", round + 1));
            }
        }
        None
    };

    let batched = rounds_for(&mut |ms, node_of| ms.run_with(&traces, &placement, node_of));
    if let Some(msg) = compare("batched", &batched) {
        return Some(msg);
    }
    for workers in WORKER_COUNTS {
        let par = rounds_for(&mut |ms, node_of| {
            ms.run_parallel(&traces, &placement, node_of, workers)
        });
        if let Some(msg) = compare(&format!("two-phase[workers={workers}]"), &par) {
            return Some(msg);
        }
    }
    for workers in WORKER_COUNTS {
        for shards in SHARD_COUNTS {
            let sharded = rounds_for(&mut |ms, node_of| {
                ms.run_sharded(&traces, &placement, node_of, workers, shards)
            });
            if let Some(msg) =
                compare(&format!("sharded[workers={workers},shards={shards}]"), &sharded)
            {
                return Some(msg);
            }
        }
    }
    None
}

// --------------------------------------------------------------------
// Kernel / measurement differential
// --------------------------------------------------------------------

/// Measure the case's kernel under its scenario with every engine and
/// compare serialized measurements (which pins traffic, FP counters and
/// the runtime estimate at once), then round-trip the reference
/// measurement through JSON and the cell store.
fn check_kernel(case: &KernelCase) -> Option<String> {
    let kernel = case.family.build();
    let spec = case.scenario.spec();
    let cache = case.scenario.cache;
    let mut machine = Machine::new(MachineConfig::xeon_6248());

    let reference = measure_kernel_reference(&mut machine, kernel.as_ref(), &spec, cache);
    let batched = measure_kernel(&mut machine, kernel.as_ref(), &spec, cache);
    let reference = match (reference, batched) {
        (Ok(r), Ok(b)) => {
            if let Some(d) = r.divergence(&b) {
                return Some(format!("batched vs reference: {d}"));
            }
            r
        }
        // The generator only emits valid cases, but a hand-edited corpus
        // file may not be measurable; that only passes if every engine
        // rejects it the same way.
        (Err(re), Err(be)) => {
            let (re, be) = (format!("{re:#}"), format!("{be:#}"));
            if re == be {
                return None;
            }
            return Some(format!("engines reject differently: '{re}' vs '{be}'"));
        }
        (Ok(_), Err(e)) => return Some(format!("batched errored, reference succeeded: {e:#}")),
        (Err(e), Ok(_)) => return Some(format!("reference errored, batched succeeded: {e:#}")),
    };
    for workers in WORKER_COUNTS {
        match measure_kernel_parallel(&mut machine, kernel.as_ref(), &spec, cache, workers) {
            Ok(m) => {
                if let Some(d) = reference.divergence(&m) {
                    return Some(format!("two-phase[workers={workers}] vs reference: {d}"));
                }
            }
            Err(e) => return Some(format!("two-phase[workers={workers}] errored: {e:#}")),
        }
    }
    for workers in WORKER_COUNTS {
        for shards in SHARD_COUNTS {
            match measure_kernel_sharded(&mut machine, kernel.as_ref(), &spec, cache, workers, shards)
            {
                Ok(m) => {
                    if let Some(d) = reference.divergence(&m) {
                        return Some(format!(
                            "sharded[workers={workers},shards={shards}] vs reference: {d}"
                        ));
                    }
                }
                Err(e) => {
                    return Some(format!(
                        "sharded[workers={workers},shards={shards}] errored: {e:#}"
                    ))
                }
            }
        }
    }
    measurement_roundtrip(&reference)
        .err()
        .map(|e| format!("measurement round-trip: {e:#}"))
}

/// The cell-store oracle: a measurement must survive JSON serialization
/// as a fixpoint and come back bit-identical from a store insert +
/// lookup (the memoizing executor's whole correctness premise).
fn measurement_roundtrip(m: &KernelMeasurement) -> Result<()> {
    let text = m.to_json().to_string_pretty();
    let back = KernelMeasurement::from_json(&Json::parse(&text)?)?;
    if back.to_json().to_string_pretty() != text {
        bail!("serialized measurement is not a fixpoint");
    }
    let dir = TempDir::new("fuzz-store");
    let store = CellStore::open(dir.path())?;
    let key = fnv1a_64(text.as_bytes());
    store.insert(key, m)?;
    match store.lookup(key) {
        Lookup::Hit(hit) => {
            if hit.to_json().to_string_pretty() != text {
                bail!("cell store returned a different measurement");
            }
        }
        other => bail!("cell store lookup after insert returned {other:?}"),
    }
    Ok(())
}

// --------------------------------------------------------------------
// Serialization round-trips
// --------------------------------------------------------------------

fn check_roundtrip(case: &RoundtripCase) -> Option<String> {
    let result = match case {
        RoundtripCase::Tar { entries } => check_tar(entries),
        RoundtripCase::Protocol { line } => check_protocol(line),
        RoundtripCase::Manifest { doc } => check_manifest(doc),
    };
    result.err().map(|e| format!("round-trip: {e:#}"))
}

fn check_tar(entries: &[(String, String)]) -> Result<()> {
    let decoded: Vec<(String, Vec<u8>)> = entries
        .iter()
        .map(|(n, h)| Ok((n.clone(), bytes_from_hex(h)?)))
        .collect::<Result<_>>()?;
    let bytes = write_tar(&decoded)?;
    let back = read_tar(&bytes)?;
    if back != decoded {
        bail!("entries changed across pack/unpack");
    }
    if write_tar(&back)? != bytes {
        bail!("repacking read entries is not byte-identical");
    }
    Ok(())
}

fn check_protocol(line: &str) -> Result<()> {
    let req = Request::parse_line(line)?;
    let emitted = req.to_line();
    let back = Request::parse_line(&emitted)?;
    if back != req {
        bail!("parse(to_line(req)) != req");
    }
    if back.to_line() != emitted {
        bail!("emission is not stable across one round-trip");
    }
    Ok(())
}

fn check_manifest(doc: &str) -> Result<()> {
    let m1 = RunManifest::from_json(&Json::parse(doc)?)?;
    let s1 = m1.to_string_pretty();
    let m2 = RunManifest::from_json(&Json::parse(&s1)?)?;
    if m2 != m1 {
        bail!("manifest changed across one round-trip");
    }
    if m2.to_string_pretty() != s1 {
        bail!("manifest serialization is not a fixpoint");
    }
    Ok(())
}

// --------------------------------------------------------------------
// Fault injection / graceful degradation
// --------------------------------------------------------------------

/// The one real measurement the faults oracle stores under injected
/// faults — simulated once per process and cloned per case, so a
/// 200-case faults session costs one simulation, not 200.
fn shared_measurement() -> KernelMeasurement {
    static CELL: std::sync::Mutex<Option<KernelMeasurement>> = std::sync::Mutex::new(None);
    let mut slot = CELL.lock().unwrap_or_else(|p| p.into_inner());
    if slot.is_none() {
        let params = crate::harness::experiments::ExperimentParams {
            batch: Some(1),
            ..Default::default()
        };
        let cells = crate::harness::spec::find("f6").expect("f6 experiment exists").cells();
        *slot = Some(cells[0].simulate(&params).expect("f6 cell simulates"));
    }
    slot.clone().expect("just filled")
}

/// The graceful-degradation oracle: replay the case's seeded fault
/// schedule against each crash-safety surface.
fn check_faults(case: &FaultsCase) -> Option<String> {
    faults_oracle(case).err().map(|e| format!("fault degradation: {e:#}"))
}

fn faults_oracle(case: &FaultsCase) -> Result<()> {
    let dir = TempDir::new("fuzz-faults");

    // Surface 1: atomic writes + reads. A faulted write either errors
    // (leaving nothing under the final name) or tears to a clean prefix;
    // a faulted read errors or truncates. So any successful read-back
    // must be a prefix of the written body — never garbage, never a
    // half-renamed tmp visible under the final name.
    let inj = FaultInjector::seeded(case.plan_seed);
    for (i, (name, body)) in case.files.iter().enumerate() {
        let path = dir.path().join(format!("{i:02}-{name}.txt"));
        let wrote = write_atomic_unique_with(&path, body, Some(&inj));
        match read_to_string_io_with(&path, Some(&inj)) {
            Ok(back) => {
                if !body.starts_with(&back) {
                    bail!("file '{name}': read back {back:?}, not a prefix of {body:?}");
                }
            }
            Err(e) => {
                if wrote.is_ok() && e.kind() == std::io::ErrorKind::NotFound {
                    bail!("file '{name}': write claimed success but the file is missing");
                }
            }
        }
    }

    // Surface 2: the cell store degrades to re-simulation, never to
    // garbage. Under any schedule a lookup is Hit (byte-identical to the
    // fault-free record), Miss, or Stale — the latter two fall back to
    // simulation, which is slower but correct.
    let meas = shared_measurement();
    let baseline = meas.to_json().to_string_pretty();
    let store = CellStore::open_with_faults(
        &dir.path().join("cache"),
        Some(std::sync::Arc::new(FaultInjector::seeded(case.plan_seed))),
    )?;
    for (i, key) in case.keys.iter().enumerate() {
        let _ = store.insert(*key, &meas); // a faulted insert may fail cleanly
        match store.lookup(*key) {
            Lookup::Hit(back) => {
                if back.to_json().to_string_pretty() != baseline {
                    bail!("key #{i}: store hit differs from the fault-free measurement");
                }
            }
            Lookup::Miss | Lookup::Stale(_) => {}
        }
    }

    // Surface 3: claim publishing degrades to simulate-anyway, and a
    // torn claim body is garbage a later claimant breaks. Either way,
    // claiming never errors out of the fill loop.
    let claims = ClaimSet::new(&dir.path().join("cache"), Duration::from_secs(600))
        .with_faults(std::sync::Arc::new(FaultInjector::seeded(case.plan_seed)));
    for key in &case.keys {
        if let ClaimOutcome::Won = claims.claim(*key)? {
            claims.release(*key);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet() -> impl FnMut(String) {
        |_msg: String| {}
    }

    #[test]
    fn roundtrip_checks_pass_on_generated_cases() {
        let mut rng = Prng::new(0xF00D);
        for _ in 0..60 {
            let case = gen::RoundtripCase::generate(&mut rng);
            assert_eq!(check_roundtrip(&case), None, "case: {case:?}");
        }
    }

    #[test]
    fn trace_differential_passes_on_shipped_engines() {
        // A focused sample; the deep sweep runs via `dlroofline fuzz`.
        let mut rng = Prng::new(0xBEEF);
        for _ in 0..10 {
            let case = gen::TraceCase::generate(&mut rng);
            assert_eq!(check_trace(&case), None, "case: {case:?}");
        }
    }

    #[test]
    fn kernel_differential_passes_on_shipped_engines() {
        let mut rng = Prng::new(0xCAFE);
        for _ in 0..2 {
            let case = gen::KernelCase::generate(&mut rng);
            assert_eq!(check_kernel(&case), None, "case: {case:?}");
        }
    }

    #[test]
    fn same_seed_same_digest() {
        let dir = TempDir::new("fuzz-det");
        let config = FuzzConfig {
            seed: 1,
            cases: 15,
            minutes: 0.0,
            corpus_dir: dir.path().to_path_buf(),
            only: None,
        };
        // Restrict to cheap kinds for the determinism probe: replace the
        // real checks with a pass-through so no kernel pipeline runs.
        let mut pass = |_: &FuzzCase| None;
        let a = run_fuzz_with(&config, &mut pass, &mut quiet()).unwrap();
        let b = run_fuzz_with(&config, &mut pass, &mut quiet()).unwrap();
        assert_eq!(a.digest, b.digest);
        assert_eq!(a.executed, 15);
        assert_eq!(
            a.trace_cases + a.kernel_cases + a.roundtrip_cases + a.faults_cases,
            a.executed
        );
        assert!(a.failure.is_none());

        let other = FuzzConfig { seed: 2, ..config };
        let c = run_fuzz_with(&other, &mut pass, &mut quiet()).unwrap();
        assert_ne!(a.digest, c.digest, "different seeds must change the digest");
    }

    #[test]
    fn broken_check_is_caught_shrunk_and_replayable() {
        let dir = TempDir::new("fuzz-broken");
        let config = FuzzConfig {
            seed: 7,
            cases: 50,
            minutes: 0.0,
            corpus_dir: dir.path().to_path_buf(),
            only: None,
        };
        // A synthetic engine bug: every trace case "diverges" (so the
        // failure is reached deterministically regardless of seed).
        let mut broken = |case: &FuzzCase| match case {
            FuzzCase::Trace(_) => Some("synthetic trace divergence".to_string()),
            _ => None,
        };
        let outcome = run_fuzz_with(&config, &mut broken, &mut quiet()).unwrap();
        let failure = outcome.failure.expect("50 cases must include a trace case");
        assert!(failure.corpus_path.exists());

        // The corpus file replays: loading gives the minimized case and
        // the recorded failure; the broken check still rejects it...
        let file = CorpusFile::load(&failure.corpus_path).unwrap();
        assert_eq!(file.failure, "synthetic trace divergence");
        assert!(broken(&file.case).is_some());
        // ...and it is genuinely minimal: one thread, one single-probe
        // load run, inert geometry.
        let FuzzCase::Trace(min) = &file.case else { panic!("wrong kind") };
        assert_eq!(min.threads(), 1);
        assert_eq!(min.runs[0].len(), 1);
        assert_eq!(min.runs[0][0].count, 1);
        assert_eq!(min.runs[0][0].kind, crate::sim::trace::AccessKind::Load);
        assert_eq!(min.nodes, 1);

        // The shipped engines agree on the shrunk case, so a real
        // replay reports the divergence as fixed.
        let (_, verdict) = replay(&failure.corpus_path).unwrap();
        assert_eq!(verdict, None);
    }

    #[test]
    fn faults_oracle_passes_on_generated_cases() {
        let mut rng = Prng::new(0xFA17);
        for _ in 0..25 {
            let case = gen::FaultsCase::generate(&mut rng);
            assert_eq!(check_faults(&case), None, "case: {case:?}");
        }
    }

    #[test]
    fn only_filter_restricts_the_stream_to_one_kind() {
        let dir = TempDir::new("fuzz-only");
        let config = FuzzConfig {
            seed: 5,
            cases: 12,
            minutes: 0.0,
            corpus_dir: dir.path().to_path_buf(),
            only: Some("faults".to_string()),
        };
        let mut pass = |_: &FuzzCase| None;
        let a = run_fuzz_with(&config, &mut pass, &mut quiet()).unwrap();
        assert_eq!(a.faults_cases, 12);
        assert_eq!(a.executed, 12);

        // Two runs of the restricted stream agree, like the full mix.
        let b = run_fuzz_with(&config, &mut pass, &mut quiet()).unwrap();
        assert_eq!(a.digest, b.digest);

        let bad = FuzzConfig { only: Some("bogus".to_string()), ..config };
        assert!(run_fuzz_with(&bad, &mut pass, &mut quiet()).is_err());
    }

    #[test]
    fn minutes_budget_truncates_without_changing_the_stream() {
        let dir = TempDir::new("fuzz-budget");
        let config = FuzzConfig {
            seed: 3,
            cases: 1000,
            minutes: 1e-9, // expires immediately
            corpus_dir: dir.path().to_path_buf(),
            only: None,
        };
        let outcome = run_fuzz_with(&config, &mut |_| None, &mut quiet()).unwrap();
        assert!(outcome.truncated);
        assert_eq!(outcome.executed, 0);
    }
}
