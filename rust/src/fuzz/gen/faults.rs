//! Generator for fault-injection cases: a seeded fault schedule plus a
//! small filesystem workload to run under it.
//!
//! The case itself is tiny — the interesting object is the
//! [`FaultPlan`](crate::util::fsutil::FaultPlan) derived from
//! `plan_seed`, which the oracle in `fuzz/mod.rs` replays against the
//! atomic-write helpers, the cell store, and the claim set. The oracle
//! is *graceful degradation*, not equality of two engines: under any
//! schedule, every operation must either fail with a clean error or
//! leave behind state indistinguishable from a slower fault-free run
//! (torn records parse as stale and re-simulate; torn claims are broken
//! as garbage; served results stay byte-identical).

use anyhow::Result;

use crate::util::json::Json;
use crate::util::prng::Prng;

use super::{u64_field, word};

/// One fault-injection case: which schedule to inject and which small
/// workload to run under it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultsCase {
    /// Seed for [`FaultPlan::generate`](crate::util::fsutil::FaultPlan::generate).
    pub plan_seed: u64,
    /// Store keys to insert/lookup under the schedule.
    pub keys: Vec<u64>,
    /// (name, body) files to write/read-back under the schedule.
    pub files: Vec<(String, String)>,
}

impl FaultsCase {
    /// Generate one case.
    pub fn generate(rng: &mut Prng) -> FaultsCase {
        let plan_seed = rng.next_u64();
        let keys = (0..rng.range(1, 5)).map(|_| rng.next_u64()).collect();
        let files = (0..rng.range(1, 5))
            .map(|_| {
                let words = rng.range(1, 5);
                let body = (0..words).map(|_| word(rng)).collect::<Vec<_>>().join(" ");
                (word(rng), body)
            })
            .collect();
        FaultsCase { plan_seed, keys, files }
    }

    /// Serialize for the corpus. Keys ride as 16-digit hex strings —
    /// JSON numbers cannot carry a full u64 exactly.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("plan_seed", Json::num(self.plan_seed as f64)),
            ("plan_seed_hex", Json::str(format!("{:016x}", self.plan_seed))),
            (
                "keys",
                Json::arr(
                    self.keys.iter().map(|k| Json::str(format!("{k:016x}"))).collect(),
                ),
            ),
            (
                "files",
                Json::arr(
                    self.files
                        .iter()
                        .map(|(name, body)| {
                            Json::obj(vec![
                                ("name", Json::str(name.as_str())),
                                ("body", Json::str(body.as_str())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Restore from the corpus form.
    pub fn from_json(v: &Json) -> Result<FaultsCase> {
        let plan_seed = match v.get("plan_seed_hex") {
            Some(hex) => u64::from_str_radix(hex.as_str()?, 16)?,
            None => u64_field(v, "plan_seed")?,
        };
        let keys = v
            .expect("keys")?
            .as_arr()?
            .iter()
            .map(|k| Ok(u64::from_str_radix(k.as_str()?, 16)?))
            .collect::<Result<Vec<u64>>>()?;
        let files = v
            .expect("files")?
            .as_arr()?
            .iter()
            .map(|f| {
                Ok((
                    f.expect("name")?.as_str()?.to_string(),
                    f.expect("body")?.as_str()?.to_string(),
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(FaultsCase { plan_seed, keys, files })
    }
}
