//! Generator for the measurement-level differential target: small
//! kernel specs × randomized scenarios beyond the six presets.
//!
//! A [`KernelCase`] pins the whole measurement pipeline — allocation,
//! overhead calibration, cache protocol, phased runtime estimate — not
//! just raw traffic, by demanding byte-identical serialized
//! [`KernelMeasurement`](crate::harness::measure::KernelMeasurement)s
//! from all three engines. Shapes are kept deliberately small (tens to
//! hundreds of KiB of footprint) so a fuzz session can afford hundreds
//! of full pipeline runs.

use anyhow::{bail, Result};

use crate::harness::cache_state::CacheState;
use crate::harness::scenario::{PlacementSpec, ScenarioSpec, ThreadSpec};
use crate::kernels::gelu::{EltwiseShape, GeluNchw};
use crate::kernels::inner_product::InnerProduct;
use crate::kernels::layernorm::LayerNorm;
use crate::kernels::pooling::{AvgPoolNchw, PoolShape};
use crate::kernels::reduction::SumReduction;
use crate::kernels::KernelModel;
use crate::sim::numa::MemPolicy;
use crate::util::json::Json;
use crate::util::prng::Prng;

use super::u64_field;

/// The generated machine has 2 sockets; every node index must stay
/// below this (scenario validation rejects out-of-range nodes).
const NODES: usize = 2;
/// Thread cap — well under one socket's 20 cores, so Bind/Unbound
/// placements always validate, while still exercising multi-thread
/// partitioning and both NUMA nodes under SpreadAll.
const MAX_THREADS: usize = 8;

/// A kernel spec drawn from the cheap model families. Conv families are
/// left to the exhaustive preset grid in `tests/sim_parity.rs` — one
/// conv measurement costs more than an entire fuzz session budget-wise.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelFamily {
    /// `SumReduction` over `n` floats.
    Reduction {
        /// Element count.
        n: usize,
    },
    /// `InnerProduct` (M×K · K×N).
    InnerProduct {
        /// Rows of A.
        m: usize,
        /// Shared dimension.
        k: usize,
        /// Columns of B.
        n: usize,
    },
    /// `GeluNchw` over an arbitrary small activation tensor.
    Gelu {
        /// Batch.
        n: usize,
        /// Channels.
        c: usize,
        /// Height.
        h: usize,
        /// Width.
        w: usize,
    },
    /// `LayerNorm` over `rows` × `hidden`.
    LayerNorm {
        /// Row count.
        rows: usize,
        /// Hidden dimension.
        hidden: usize,
    },
    /// `AvgPoolNchw` over a small input plane.
    AvgPool {
        /// Channels.
        c: usize,
        /// Input height.
        ih: usize,
        /// Input width.
        iw: usize,
        /// Window size.
        kernel: usize,
        /// Window stride.
        stride: usize,
    },
}

impl KernelFamily {
    /// Instantiate the kernel model.
    pub fn build(&self) -> Box<dyn KernelModel> {
        match *self {
            KernelFamily::Reduction { n } => Box::new(SumReduction::new(n)),
            KernelFamily::InnerProduct { m, k, n } => Box::new(InnerProduct::new(m, k, n)),
            KernelFamily::Gelu { n, c, h, w } => {
                Box::new(GeluNchw::new(EltwiseShape { n, c, h, w }))
            }
            KernelFamily::LayerNorm { rows, hidden } => Box::new(LayerNorm::new(rows, hidden)),
            KernelFamily::AvgPool { c, ih, iw, kernel, stride } => Box::new(AvgPoolNchw::new(
                PoolShape { n: 1, c, ih, iw, kernel, stride },
            )),
        }
    }

    fn generate(rng: &mut Prng) -> KernelFamily {
        match rng.range(0, 5) {
            0 => KernelFamily::Reduction { n: rng.range(1024, 65537) },
            1 => KernelFamily::InnerProduct {
                m: rng.range(8, 97),
                k: rng.range(8, 97),
                n: rng.range(8, 97),
            },
            2 => KernelFamily::Gelu {
                n: 1,
                c: rng.range(4, 33),
                h: rng.range(4, 33),
                w: rng.range(4, 33),
            },
            3 => KernelFamily::LayerNorm { rows: rng.range(8, 129), hidden: rng.range(32, 513) },
            _ => {
                let kernel = rng.range(2, 4);
                KernelFamily::AvgPool {
                    c: rng.range(2, 17),
                    ih: rng.range(kernel + 4, 41),
                    iw: rng.range(kernel + 4, 41),
                    kernel,
                    stride: rng.range(1, 3),
                }
            }
        }
    }

    /// Clamp every dimension back into a valid, affordable shape.
    pub fn sanitize(&mut self) {
        match self {
            KernelFamily::Reduction { n } => *n = (*n).clamp(1, 1 << 20),
            KernelFamily::InnerProduct { m, k, n } => {
                *m = (*m).clamp(1, 256);
                *k = (*k).clamp(1, 256);
                *n = (*n).clamp(1, 256);
            }
            KernelFamily::Gelu { n, c, h, w } => {
                *n = (*n).clamp(1, 4);
                *c = (*c).clamp(1, 64);
                *h = (*h).clamp(1, 64);
                *w = (*w).clamp(1, 64);
            }
            KernelFamily::LayerNorm { rows, hidden } => {
                *rows = (*rows).clamp(1, 512);
                *hidden = (*hidden).clamp(1, 1024);
            }
            KernelFamily::AvgPool { c, ih, iw, kernel, stride } => {
                *kernel = (*kernel).clamp(1, 7);
                *stride = (*stride).clamp(1, 4);
                *c = (*c).clamp(1, 32);
                *ih = (*ih).clamp(*kernel, 64);
                *iw = (*iw).clamp(*kernel, 64);
            }
        }
    }

    /// Corpus form.
    pub fn to_json(&self) -> Json {
        match *self {
            KernelFamily::Reduction { n } => Json::obj(vec![
                ("family", Json::str("reduction")),
                ("n", Json::num(n as f64)),
            ]),
            KernelFamily::InnerProduct { m, k, n } => Json::obj(vec![
                ("family", Json::str("inner_product")),
                ("m", Json::num(m as f64)),
                ("k", Json::num(k as f64)),
                ("n", Json::num(n as f64)),
            ]),
            KernelFamily::Gelu { n, c, h, w } => Json::obj(vec![
                ("family", Json::str("gelu")),
                ("n", Json::num(n as f64)),
                ("c", Json::num(c as f64)),
                ("h", Json::num(h as f64)),
                ("w", Json::num(w as f64)),
            ]),
            KernelFamily::LayerNorm { rows, hidden } => Json::obj(vec![
                ("family", Json::str("layernorm")),
                ("rows", Json::num(rows as f64)),
                ("hidden", Json::num(hidden as f64)),
            ]),
            KernelFamily::AvgPool { c, ih, iw, kernel, stride } => Json::obj(vec![
                ("family", Json::str("avgpool")),
                ("c", Json::num(c as f64)),
                ("ih", Json::num(ih as f64)),
                ("iw", Json::num(iw as f64)),
                ("kernel", Json::num(kernel as f64)),
                ("stride", Json::num(stride as f64)),
            ]),
        }
    }

    /// Restore from the corpus form (sanitized on load).
    pub fn from_json(v: &Json) -> Result<KernelFamily> {
        let dim = |key: &str| -> Result<usize> { Ok(u64_field(v, key)? as usize) };
        let mut family = match v.expect("family")?.as_str()? {
            "reduction" => KernelFamily::Reduction { n: dim("n")? },
            "inner_product" => {
                KernelFamily::InnerProduct { m: dim("m")?, k: dim("k")?, n: dim("n")? }
            }
            "gelu" => KernelFamily::Gelu { n: dim("n")?, c: dim("c")?, h: dim("h")?, w: dim("w")? },
            "layernorm" => KernelFamily::LayerNorm { rows: dim("rows")?, hidden: dim("hidden")? },
            "avgpool" => KernelFamily::AvgPool {
                c: dim("c")?,
                ih: dim("ih")?,
                iw: dim("iw")?,
                kernel: dim("kernel")?,
                stride: dim("stride")?,
            },
            other => bail!("unknown kernel family '{other}'"),
        };
        family.sanitize();
        Ok(family)
    }
}

/// A randomized scenario: the fuzzer explores the full threads ×
/// placement × mem-policy cube, not just the six shipped presets.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScenarioCase {
    /// Thread count (≤ [`MAX_THREADS`]).
    pub threads: usize,
    /// Placement spec.
    pub placement: PlacementSpec,
    /// Memory policy.
    pub mem: MemPolicy,
    /// Cache protocol.
    pub cache: CacheState,
}

impl ScenarioCase {
    /// Build the harness scenario spec.
    pub fn spec(&self) -> ScenarioSpec {
        ScenarioSpec::custom("fuzz", ThreadSpec::Fixed(self.threads), self.placement, self.mem)
    }

    fn generate(rng: &mut Prng) -> ScenarioCase {
        let placement = match rng.range(0, 4) {
            0 => PlacementSpec::Bind(rng.range(0, NODES)),
            1 => PlacementSpec::SpreadAll,
            2 => PlacementSpec::Unbound(rng.range(0, NODES)),
            _ => PlacementSpec::Bind(0),
        };
        let mem = match rng.range(0, 3) {
            0 => MemPolicy::BindNode(rng.range(0, NODES)),
            1 => MemPolicy::Interleave,
            _ => MemPolicy::FirstTouch,
        };
        let cache = if rng.chance(0.3) { CacheState::Warm } else { CacheState::Cold };
        ScenarioCase { threads: rng.range(1, MAX_THREADS + 1), placement, mem, cache }
    }

    /// Clamp thread count and node indices into the generated machine.
    pub fn sanitize(&mut self) {
        self.threads = self.threads.clamp(1, MAX_THREADS);
        match &mut self.placement {
            PlacementSpec::Bind(n) | PlacementSpec::Unbound(n) => *n = (*n).min(NODES - 1),
            PlacementSpec::SpreadAll => {}
        }
        if let MemPolicy::BindNode(n) = &mut self.mem {
            *n = (*n).min(NODES - 1);
        }
    }

    /// Corpus form.
    pub fn to_json(&self) -> Json {
        let (placement, node) = match self.placement {
            PlacementSpec::Bind(n) => ("bind", n),
            PlacementSpec::SpreadAll => ("spread_all", 0),
            PlacementSpec::Unbound(n) => ("unbound", n),
        };
        let (mem, mem_node) = match self.mem {
            MemPolicy::BindNode(n) => ("bind_node", n),
            MemPolicy::Interleave => ("interleave", 0),
            MemPolicy::FirstTouch => ("first_touch", 0),
        };
        Json::obj(vec![
            ("threads", Json::num(self.threads as f64)),
            ("placement", Json::str(placement)),
            ("placement_node", Json::num(node as f64)),
            ("mem", Json::str(mem)),
            ("mem_node", Json::num(mem_node as f64)),
            ("cache", Json::str(self.cache.label())),
        ])
    }

    /// Restore from the corpus form (sanitized on load).
    pub fn from_json(v: &Json) -> Result<ScenarioCase> {
        let node = u64_field(v, "placement_node")? as usize;
        let placement = match v.expect("placement")?.as_str()? {
            "bind" => PlacementSpec::Bind(node),
            "spread_all" => PlacementSpec::SpreadAll,
            "unbound" => PlacementSpec::Unbound(node),
            other => bail!("unknown placement '{other}'"),
        };
        let mem_node = u64_field(v, "mem_node")? as usize;
        let mem = match v.expect("mem")?.as_str()? {
            "bind_node" => MemPolicy::BindNode(mem_node),
            "interleave" => MemPolicy::Interleave,
            "first_touch" => MemPolicy::FirstTouch,
            other => bail!("unknown mem policy '{other}'"),
        };
        let cache = match v.expect("cache")?.as_str()? {
            "cold" => CacheState::Cold,
            "warm" => CacheState::Warm,
            other => bail!("unknown cache protocol '{other}'"),
        };
        let mut case =
            ScenarioCase { threads: u64_field(v, "threads")? as usize, placement, mem, cache };
        case.sanitize();
        Ok(case)
    }
}

/// One complete measurement-differential case.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KernelCase {
    /// Kernel spec.
    pub family: KernelFamily,
    /// Scenario to measure it under.
    pub scenario: ScenarioCase,
}

impl KernelCase {
    /// Draw a complete case.
    pub fn generate(rng: &mut Prng) -> KernelCase {
        KernelCase { family: KernelFamily::generate(rng), scenario: ScenarioCase::generate(rng) }
    }

    /// Re-clamp both halves (used after shrinking mutations).
    pub fn sanitize(&mut self) {
        self.family.sanitize();
        self.scenario.sanitize();
    }

    /// Corpus form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kernel", self.family.to_json()),
            ("scenario", self.scenario.to_json()),
        ])
    }

    /// Restore from the corpus form.
    pub fn from_json(v: &Json) -> Result<KernelCase> {
        Ok(KernelCase {
            family: KernelFamily::from_json(v.expect("kernel")?)?,
            scenario: ScenarioCase::from_json(v.expect("scenario")?)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::machine::MachineConfig;

    #[test]
    fn generated_scenarios_always_validate() {
        let config = MachineConfig::xeon_6248();
        let mut rng = Prng::new(11);
        for _ in 0..128 {
            let case = KernelCase::generate(&mut rng);
            case.scenario.spec().validate(&config).unwrap();
            let back = KernelCase::from_json(&case.to_json()).unwrap();
            assert_eq!(back, case);
        }
    }

    #[test]
    fn sanitize_repairs_out_of_range_scenarios() {
        let mut case = ScenarioCase {
            threads: 999,
            placement: PlacementSpec::Unbound(7),
            mem: MemPolicy::BindNode(7),
            cache: CacheState::Cold,
        };
        case.sanitize();
        assert_eq!(case.threads, MAX_THREADS);
        assert_eq!(case.placement, PlacementSpec::Unbound(1));
        assert_eq!(case.mem, MemPolicy::BindNode(1));
        case.spec().validate(&MachineConfig::xeon_6248()).unwrap();
    }

    #[test]
    fn degenerate_family_dims_stay_buildable() {
        let mut f = KernelFamily::AvgPool { c: 0, ih: 0, iw: 0, kernel: 0, stride: 0 };
        f.sanitize();
        let _ = f.build(); // PoolShape::oh()/ow() must not underflow
        let mut g = KernelFamily::Reduction { n: 0 };
        g.sanitize();
        let _ = g.build();
    }
}
