//! Seeded case generators for the differential fuzzer.
//!
//! Every generator draws from a [`Prng`](crate::util::prng::Prng) seeded
//! with the case seed, so a case is a pure function of its seed: the
//! same `--seed` always produces the same case stream, and a failing
//! case can be regenerated (or replayed from its serialized form in the
//! corpus — see [`crate::fuzz::corpus`]).
//!
//! Four case kinds cover the crate's correctness surfaces:
//!
//! - [`TraceCase`] (`gen/trace.rs`): arbitrary access traces × cache
//!   geometries (including degenerate 1-way / single-set / tiny-LLC
//!   shapes) × placements × page→node maps, run through all three
//!   simulator engines and compared bit-for-bit.
//! - [`KernelCase`] (`gen/kernel.rs`): kernel specs × randomized
//!   [`ScenarioSpec`](crate::harness::scenario::ScenarioSpec)s beyond
//!   the six presets × cache protocols, compared at the measurement
//!   level (serialized [`KernelMeasurement`](crate::harness::measure::KernelMeasurement)s
//!   must be byte-identical) plus cell-store round-trip oracles.
//! - [`RoundtripCase`] (this module): serialization surfaces — run
//!   manifests, the deterministic ustar packer, and the serve wire
//!   protocol — must all round-trip exactly.
//! - [`FaultsCase`] (`gen/faults.rs`): seeded fault schedules replayed
//!   against the atomic-write helpers, the cell store, and the claim
//!   set; the oracle is graceful degradation (clean error or state
//!   indistinguishable from a fault-free run), not engine equality.

use anyhow::{bail, Context, Result};

use crate::coordinator::manifest::{CellRecord, FileRecord, RunManifest};
use crate::roofline::point::LevelBytes;
use crate::serve::protocol::{Request, SubmitRequest};
use crate::util::json::Json;
use crate::util::prng::Prng;

pub mod faults;
pub mod kernel;
pub mod trace;

pub use faults::FaultsCase;
pub use kernel::KernelCase;
pub use trace::TraceCase;

/// One generated fuzz case of any kind.
#[derive(Clone, Debug, PartialEq)]
pub enum FuzzCase {
    /// Raw trace differential across the three engines.
    Trace(TraceCase),
    /// Measurement-level differential plus store round-trip.
    Kernel(KernelCase),
    /// Serialization surface round-trip.
    Roundtrip(RoundtripCase),
    /// Fault schedule replayed against the crash-safety surfaces.
    Faults(FaultsCase),
}

impl FuzzCase {
    /// Case kind label, as recorded in corpus files.
    pub fn kind(&self) -> &'static str {
        match self {
            FuzzCase::Trace(_) => "trace",
            FuzzCase::Kernel(_) => "kernel",
            FuzzCase::Roundtrip(_) => "roundtrip",
            FuzzCase::Faults(_) => "faults",
        }
    }

    /// Generate one case from a per-case seed. Kind weights favour the
    /// trace differential (the widest input space); kernel cases are
    /// rarer because each one runs the full measurement pipeline five
    /// times.
    pub fn generate(case_seed: u64) -> FuzzCase {
        let mut rng = Prng::new(case_seed);
        let draw = rng.f64();
        if draw < 0.40 {
            FuzzCase::Trace(TraceCase::generate(&mut rng))
        } else if draw < 0.63 {
            FuzzCase::Kernel(KernelCase::generate(&mut rng))
        } else if draw < 0.88 {
            FuzzCase::Roundtrip(RoundtripCase::generate(&mut rng))
        } else {
            FuzzCase::Faults(FaultsCase::generate(&mut rng))
        }
    }

    /// Generate one case of a fixed kind (the `fuzz --only` filter).
    /// Draws from the same per-case rng as [`FuzzCase::generate`] minus
    /// the kind draw, so a kind's case stream is still a pure function
    /// of the seed stream.
    pub fn generate_only(kind: &str, case_seed: u64) -> Result<FuzzCase> {
        let mut rng = Prng::new(case_seed);
        match kind {
            "trace" => Ok(FuzzCase::Trace(TraceCase::generate(&mut rng))),
            "kernel" => Ok(FuzzCase::Kernel(KernelCase::generate(&mut rng))),
            "roundtrip" => Ok(FuzzCase::Roundtrip(RoundtripCase::generate(&mut rng))),
            "faults" => Ok(FuzzCase::Faults(FaultsCase::generate(&mut rng))),
            other => bail!("unknown fuzz case kind '{other}' (trace|kernel|roundtrip|faults)"),
        }
    }

    /// Serialize the concrete case (not just its seed) so corpus files
    /// stay replayable even if the generators later change.
    pub fn to_json(&self) -> Json {
        match self {
            FuzzCase::Trace(c) => c.to_json(),
            FuzzCase::Kernel(c) => c.to_json(),
            FuzzCase::Roundtrip(c) => c.to_json(),
            FuzzCase::Faults(c) => c.to_json(),
        }
    }

    /// Restore a case from its corpus form, given the recorded kind.
    pub fn from_json(kind: &str, v: &Json) -> Result<FuzzCase> {
        match kind {
            "trace" => Ok(FuzzCase::Trace(TraceCase::from_json(v)?)),
            "kernel" => Ok(FuzzCase::Kernel(KernelCase::from_json(v)?)),
            "roundtrip" => Ok(FuzzCase::Roundtrip(RoundtripCase::from_json(v)?)),
            "faults" => Ok(FuzzCase::Faults(FaultsCase::from_json(v)?)),
            other => bail!("unknown fuzz case kind '{other}'"),
        }
    }
}

/// A serialization-surface round-trip case. Each variant pins one
/// "parse ∘ emit = identity" contract the rest of the system depends on
/// (cache records, artifacts, and the serve protocol all assume it).
#[derive(Clone, Debug, PartialEq)]
pub enum RoundtripCase {
    /// `write_tar` → `read_tar` must return the exact entries, and
    /// repacking the read entries must be byte-identical.
    Tar {
        /// Entries as (name, hex-encoded body).
        entries: Vec<(String, String)>,
    },
    /// `Request::parse_line` ∘ `Request::to_line` must be the identity.
    Protocol {
        /// One request wire line.
        line: String,
    },
    /// `RunManifest::from_json` ∘ `to_json` must be the identity, for
    /// v1 and v2 documents alike.
    Manifest {
        /// The manifest document text.
        doc: String,
    },
}

impl RoundtripCase {
    /// Generate one round-trip case.
    pub fn generate(rng: &mut Prng) -> RoundtripCase {
        match rng.range(0, 3) {
            0 => RoundtripCase::Tar { entries: gen_tar_entries(rng) },
            1 => RoundtripCase::Protocol { line: gen_request(rng).to_line() },
            _ => RoundtripCase::Manifest { doc: gen_manifest(rng).to_string_pretty() },
        }
    }

    /// Serialize for the corpus.
    pub fn to_json(&self) -> Json {
        match self {
            RoundtripCase::Tar { entries } => Json::obj(vec![
                ("surface", Json::str("tar")),
                (
                    "entries",
                    Json::arr(
                        entries
                            .iter()
                            .map(|(name, hex)| {
                                Json::obj(vec![
                                    ("name", Json::str(name.as_str())),
                                    ("body_hex", Json::str(hex.as_str())),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            RoundtripCase::Protocol { line } => Json::obj(vec![
                ("surface", Json::str("protocol")),
                ("line", Json::str(line.as_str())),
            ]),
            RoundtripCase::Manifest { doc } => Json::obj(vec![
                ("surface", Json::str("manifest")),
                ("doc", Json::str(doc.as_str())),
            ]),
        }
    }

    /// Restore from the corpus form.
    pub fn from_json(v: &Json) -> Result<RoundtripCase> {
        let surface = v.expect("surface")?.as_str()?;
        match surface {
            "tar" => Ok(RoundtripCase::Tar {
                entries: v
                    .expect("entries")?
                    .as_arr()?
                    .iter()
                    .map(|e| {
                        Ok((
                            e.expect("name")?.as_str()?.to_string(),
                            e.expect("body_hex")?.as_str()?.to_string(),
                        ))
                    })
                    .collect::<Result<Vec<_>>>()?,
            }),
            "protocol" => Ok(RoundtripCase::Protocol {
                line: v.expect("line")?.as_str()?.to_string(),
            }),
            "manifest" => Ok(RoundtripCase::Manifest {
                doc: v.expect("doc")?.as_str()?.to_string(),
            }),
            other => bail!("unknown roundtrip surface '{other}'"),
        }
    }
}

/// A lowercase alphanumeric identifier, 3–10 chars.
pub fn word(rng: &mut Prng) -> String {
    const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789";
    let len = rng.range(3, 11);
    (0..len).map(|_| CHARS[rng.range(0, CHARS.len())] as char).collect()
}

/// Hex-encode a byte body for corpus storage.
pub fn hex_bytes(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len() * 2);
    for b in data {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

/// Decode a corpus hex body.
pub fn bytes_from_hex(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        bail!("odd-length hex body");
    }
    (0..s.len() / 2)
        .map(|i| u8::from_str_radix(&s[2 * i..2 * i + 2], 16).context("bad hex byte"))
        .collect()
}

/// Parse a JSON number field as an exact non-negative integer.
pub(crate) fn u64_field(v: &Json, key: &str) -> Result<u64> {
    let x = v.expect(key)?.as_f64()?;
    if !(x >= 0.0 && x.fract() == 0.0 && x < 9.0e15) {
        bail!("field '{key}' must be a non-negative integer, got {x}");
    }
    Ok(x as u64)
}

fn gen_tar_entries(rng: &mut Prng) -> Vec<(String, String)> {
    let mut seen = std::collections::BTreeSet::new();
    let mut entries = Vec::new();
    for _ in 0..rng.range(1, 7) {
        let segments = rng.range(1, 4);
        let name = (0..segments).map(|_| word(rng)).collect::<Vec<_>>().join("/");
        if !seen.insert(name.clone()) {
            continue; // duplicate names are rejected by write_tar by design
        }
        // Bias bodies toward tar block boundaries (0, 512, 1024) where
        // padding bugs would live.
        let len = match rng.range(0, 5) {
            0 => 0,
            1 => 512,
            2 => 1024,
            _ => rng.range(1, 600),
        };
        let mut body = Vec::with_capacity(len);
        while body.len() < len {
            body.extend_from_slice(&rng.next_u64().to_le_bytes());
        }
        body.truncate(len);
        entries.push((name, hex_bytes(&body)));
    }
    entries
}

fn gen_request(rng: &mut Prng) -> Request {
    match rng.range(0, 6) {
        0 => Request::Ping,
        1 => Request::List,
        2 => Request::Shutdown,
        3 => {
            let experiments = (0..rng.range(1, 4)).map(|_| word(rng)).collect();
            Request::Submit(SubmitRequest {
                experiments,
                machine: if rng.chance(0.5) { Some(word(rng)) } else { None },
                batch: if rng.chance(0.5) { Some(rng.range(1, 64)) } else { None },
                full_size: rng.chance(0.5),
                svg: rng.chance(0.5),
            })
        }
        4 => Request::Status { job: word(rng), cells: rng.chance(0.5) },
        _ => Request::Fetch { job: word(rng), file: word(rng) },
    }
}

/// A finite positive float whose text form exercises the shortest
/// round-trip emitter (mantissa-heavy values, not round numbers).
fn gen_float(rng: &mut Prng) -> f64 {
    let scale = [1e-6, 1e-3, 1.0, 1e3, 1e9][rng.range(0, 5)];
    rng.f64() * scale
}

fn gen_manifest(rng: &mut Prng) -> RunManifest {
    let schema_version = if rng.chance(0.3) { 1 } else { 2 };
    let cells = (0..rng.range(0, 4))
        .map(|_| CellRecord {
            experiment: word(rng),
            kernel: word(rng),
            scenario: word(rng),
            cache: if rng.chance(0.5) { "cold".into() } else { "warm".into() },
            key: format!("{:016x}", rng.next_u64()),
            reused: rng.chance(0.5),
            threads: rng.range(1, 41),
            work_flops: rng.below(1 << 50),
            traffic_bytes: rng.below(1 << 50),
            runtime_seconds: gen_float(rng),
            levels: if schema_version == 2 {
                Some(LevelBytes {
                    l1: gen_float(rng),
                    l2: gen_float(rng),
                    llc: gen_float(rng),
                    dram_local: gen_float(rng),
                    dram_remote: gen_float(rng),
                })
            } else {
                None
            },
        })
        .collect();
    let files = (0..rng.range(0, 3))
        .map(|_| FileRecord {
            path: format!("{}.md", word(rng)),
            bytes: rng.below(1 << 30),
            checksum: format!("fnv1a64:{:016x}", rng.next_u64()),
        })
        .collect();
    RunManifest {
        schema_version,
        generator: format!("dlroofline {}", word(rng)),
        machine: Json::obj(vec![
            ("name", Json::str(word(rng))),
            ("sockets", Json::num(rng.range(1, 3) as f64)),
        ]),
        machine_fingerprint: format!("{:016x}", rng.next_u64()),
        full_size: rng.chance(0.5),
        batch: if rng.chance(0.5) { Some(rng.range(1, 129)) } else { None },
        experiments: (0..rng.range(1, 4)).map(|_| word(rng)).collect(),
        specials: rng.range(0, 3),
        cells_skipped: rng.range(0, 3),
        cells,
        files,
    }
}
