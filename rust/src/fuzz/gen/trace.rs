//! Generator for the trace-differential target: arbitrary access
//! traces, cache geometries (including degenerate shapes the presets
//! never build), NUMA placements, and page→node maps.
//!
//! A [`TraceCase`] is fully self-describing — everything needed to
//! rebuild the [`MemorySystem`](crate::sim::hierarchy::MemorySystem)
//! and replay the exact access stream lives in the case, so corpus
//! files survive generator changes. All numeric fields are kept below
//! 2^53 so they serialize exactly through the f64-backed JSON layer.

use anyhow::{bail, Result};

use crate::sim::cache::CacheConfig;
use crate::sim::hierarchy::HierarchyConfig;
use crate::sim::prefetch::PrefetchConfig;
use crate::sim::trace::{AccessKind, AccessRun, Trace};
use crate::util::json::Json;
use crate::util::prng::Prng;

use super::u64_field;

/// Associativity choices drawn by the generator (1 = direct-mapped).
const WAY_CHOICES: [usize; 5] = [1, 2, 4, 8, 16];
/// Access-size choices: sub-word, word, vector, line, multi-line.
const SIZE_CHOICES: [u32; 5] = [1, 4, 8, 64, 256];
/// Strides worth hitting often: line-aligned, off-by-one-line (split
/// probes), page-sized (defeats the prefetcher), and backwards.
const STRIDE_CHOICES: [i64; 9] = [0, 1, 4, 63, 64, 65, -64, 4096, -4096];

/// Upper bound (exclusive) for generated base addresses. Far below the
/// simulator's 2^38-byte address-space cap and the 2^53 JSON-exactness
/// cap, with room for `count * stride` on top.
const BASE_SPAN: u64 = 1 << 32;

/// A cache geometry expressed as sets × ways per level, so the
/// generator can build shapes the presets never do: direct-mapped L1s,
/// single-set levels, an LLC smaller than L1.
#[derive(Clone, Debug, PartialEq)]
pub struct GeometryCase {
    /// L1 set count.
    pub l1_sets: usize,
    /// L1 associativity.
    pub l1_ways: usize,
    /// L2 set count.
    pub l2_sets: usize,
    /// L2 associativity.
    pub l2_ways: usize,
    /// Shared-LLC set count.
    pub llc_sets: usize,
    /// Shared-LLC associativity.
    pub llc_ways: usize,
    /// Hardware prefetcher on?
    pub prefetch: bool,
}

impl GeometryCase {
    /// Build the simulator config. `CacheConfig::new` asserts
    /// `sets * ways * 64 == size`, so sizes are derived from the shape.
    pub fn hierarchy(&self) -> HierarchyConfig {
        let cache = |sets: usize, ways: usize| CacheConfig::new((sets * ways * 64) as u64, ways);
        HierarchyConfig {
            l1: cache(self.l1_sets, self.l1_ways),
            l2: cache(self.l2_sets, self.l2_ways),
            llc: cache(self.llc_sets, self.llc_ways),
            prefetch: if self.prefetch {
                PrefetchConfig::default()
            } else {
                PrefetchConfig::disabled()
            },
        }
    }

    /// Draw a geometry. Degenerate shapes (1-way, single-set, tiny LLC)
    /// are first-class draws, not rare corners: conflict-miss and
    /// eviction-order bugs live there.
    pub fn generate(rng: &mut Prng) -> GeometryCase {
        let sets = |rng: &mut Prng, max_pow: usize| {
            if rng.chance(0.25) {
                1 // single-set level
            } else {
                1usize << rng.range(0, max_pow + 1)
            }
        };
        let ways = |rng: &mut Prng| *rng.pick(&WAY_CHOICES);
        let mut g = GeometryCase {
            l1_sets: sets(rng, 6),
            l1_ways: ways(rng),
            l2_sets: sets(rng, 8),
            l2_ways: ways(rng),
            llc_sets: sets(rng, 9),
            llc_ways: ways(rng),
            prefetch: rng.chance(0.6),
        };
        if rng.chance(0.2) {
            // Tiny LLC: smaller than the private levels above it, so
            // inclusive-fill bookkeeping is stressed hard.
            g.llc_sets = 1;
            g.llc_ways = *rng.pick(&[1usize, 2]);
        }
        g
    }

    /// Serialize for the corpus.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("l1_sets", Json::num(self.l1_sets as f64)),
            ("l1_ways", Json::num(self.l1_ways as f64)),
            ("l2_sets", Json::num(self.l2_sets as f64)),
            ("l2_ways", Json::num(self.l2_ways as f64)),
            ("llc_sets", Json::num(self.llc_sets as f64)),
            ("llc_ways", Json::num(self.llc_ways as f64)),
            ("prefetch", Json::Bool(self.prefetch)),
        ])
    }

    /// Restore from the corpus form, bounding the shape so a
    /// hand-edited corpus file cannot allocate an absurd simulator.
    pub fn from_json(v: &Json) -> Result<GeometryCase> {
        let dim = |key: &str| -> Result<usize> {
            let x = u64_field(v, key)?;
            if !(1..=65536).contains(&x) {
                bail!("geometry field '{key}' out of range: {x}");
            }
            Ok(x as usize)
        };
        Ok(GeometryCase {
            l1_sets: dim("l1_sets")?,
            l1_ways: dim("l1_ways")?,
            l2_sets: dim("l2_sets")?,
            l2_ways: dim("l2_ways")?,
            llc_sets: dim("llc_sets")?,
            llc_ways: dim("llc_ways")?,
            prefetch: v.expect("prefetch")?.as_bool()?,
        })
    }
}

/// A pure, order-independent page→node map. The two-phase engine may
/// resolve nodes in a different interleaving than the reference, so the
/// map must be a function of `(addr, toucher)` alone — these mirror the
/// first-touch/interleave/bind policies without the stateful `PageMap`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NodeMap {
    /// Everything on node 0.
    Zero,
    /// Node = page number mod nodes (interleave-like).
    PageMod,
    /// Node = (page ^ toucher) mod nodes (placement-sensitive).
    PageXorToucher,
    /// Node = a high address bit (two large bound regions).
    HighBit,
}

impl NodeMap {
    /// Resolve the owning node for a line address touched by `toucher`.
    pub fn node_of(&self, nodes: usize, addr: u64, toucher: usize) -> usize {
        if nodes <= 1 {
            return 0;
        }
        let page = (addr >> 12) as usize;
        match self {
            NodeMap::Zero => 0,
            NodeMap::PageMod => page % nodes,
            NodeMap::PageXorToucher => (page ^ toucher) % nodes,
            NodeMap::HighBit => ((addr >> 28) as usize) % nodes,
        }
    }

    /// Corpus label.
    pub fn label(&self) -> &'static str {
        match self {
            NodeMap::Zero => "zero",
            NodeMap::PageMod => "page_mod",
            NodeMap::PageXorToucher => "page_xor_toucher",
            NodeMap::HighBit => "high_bit",
        }
    }

    /// Parse a corpus label.
    pub fn parse(s: &str) -> Result<NodeMap> {
        Ok(match s {
            "zero" => NodeMap::Zero,
            "page_mod" => NodeMap::PageMod,
            "page_xor_toucher" => NodeMap::PageXorToucher,
            "high_bit" => NodeMap::HighBit,
            other => bail!("unknown node map '{other}'"),
        })
    }
}

/// One access run of a generated trace (mirrors
/// [`AccessRun`](crate::sim::trace::AccessRun), plus corpus
/// serialization and sanitization).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RunCase {
    /// First probe address.
    pub base: u64,
    /// Signed per-probe stride in bytes.
    pub stride: i64,
    /// Probe count (≥ 1).
    pub count: u64,
    /// Bytes per probe.
    pub size: u32,
    /// Access kind.
    pub kind: AccessKind,
}

impl RunCase {
    /// Clamp every field into the simulator's documented contract:
    /// positive count, bounded stride/size, and the
    /// [`AccessRun::no_wrap`] address contract. Ascending runs satisfy
    /// it structurally after the clamps (`base < 2^32`, `|stride| ≤
    /// 2^16`, `count ≤ 2^12` ⇒ last address `< 2^33 ≪ i64::MAX`);
    /// descending runs additionally get `base` lifted to the run's
    /// reach so the last address stays ≥ 0.
    pub fn sanitize(&mut self) {
        self.count = self.count.clamp(1, 4096);
        self.size = self.size.clamp(1, 512);
        self.stride = self.stride.clamp(-65536, 65536);
        self.base %= BASE_SPAN;
        if self.stride < 0 {
            let reach = self.stride.unsigned_abs() * (self.count - 1);
            if self.base < reach {
                self.base = reach;
            }
        }
    }

    /// Convert to a simulator access run.
    pub fn to_run(&self) -> AccessRun {
        AccessRun { base: self.base, stride: self.stride, count: self.count, size: self.size, kind: self.kind }
    }

    fn kind_label(kind: AccessKind) -> &'static str {
        match kind {
            AccessKind::Load => "load",
            AccessKind::Store => "store",
            AccessKind::StoreNT => "store_nt",
            AccessKind::PrefetchSW => "prefetch_sw",
        }
    }

    fn parse_kind(s: &str) -> Result<AccessKind> {
        Ok(match s {
            "load" => AccessKind::Load,
            "store" => AccessKind::Store,
            "store_nt" => AccessKind::StoreNT,
            "prefetch_sw" => AccessKind::PrefetchSW,
            other => bail!("unknown access kind '{other}'"),
        })
    }

    /// Serialize for the corpus.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("base", Json::num(self.base as f64)),
            ("stride", Json::num(self.stride as f64)),
            ("count", Json::num(self.count as f64)),
            ("size", Json::num(self.size as f64)),
            ("kind", Json::str(Self::kind_label(self.kind))),
        ])
    }

    /// Restore from the corpus form (re-sanitized on load).
    pub fn from_json(v: &Json) -> Result<RunCase> {
        let stride = v.expect("stride")?.as_f64()?;
        if stride.fract() != 0.0 || stride.abs() > 9.0e15 {
            bail!("stride must be an integer, got {stride}");
        }
        let mut run = RunCase {
            base: u64_field(v, "base")?,
            stride: stride as i64,
            count: u64_field(v, "count")?,
            size: u64_field(v, "size")?.min(u32::MAX as u64) as u32,
            kind: Self::parse_kind(v.expect("kind")?.as_str()?)?,
        };
        run.sanitize();
        Ok(run)
    }

    fn generate(rng: &mut Prng, bases: &mut Vec<u64>) -> RunCase {
        // Reuse an earlier base ~40% of the time (plus a small line-ish
        // delta) so runs and threads alias the same lines — shared-line
        // coherence is where the engines could disagree.
        let base = if !bases.is_empty() && rng.chance(0.4) {
            let prior = bases[rng.range(0, bases.len())];
            let delta = [0i64, 8, 64, -64, 4096][rng.range(0, 5)];
            prior.wrapping_add_signed(delta) % BASE_SPAN
        } else if rng.chance(0.5) {
            rng.below(BASE_SPAN) & !4095 // page-aligned
        } else {
            rng.below(BASE_SPAN)
        };
        let stride = if rng.chance(0.8) {
            *rng.pick(&STRIDE_CHOICES)
        } else {
            rng.below(131073) as i64 - 65536
        };
        let kind = match rng.range(0, 10) {
            0..=5 => AccessKind::Load,
            6..=7 => AccessKind::Store,
            8 => AccessKind::StoreNT,
            _ => AccessKind::PrefetchSW,
        };
        let mut run = RunCase {
            base,
            stride,
            count: 1 + rng.below(2048),
            size: *rng.pick(&SIZE_CHOICES),
            kind,
        };
        run.sanitize();
        bases.push(run.base);
        run
    }
}

/// One complete trace-differential case.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceCase {
    /// Cache geometry for all three engines.
    pub geometry: GeometryCase,
    /// NUMA node count (1 or 2).
    pub nodes: usize,
    /// Home node per thread (`thread_nodes[t] < nodes`); one thread per
    /// trace, like the harness.
    pub thread_nodes: Vec<usize>,
    /// Pure page→node map shared by all engines.
    pub node_map: NodeMap,
    /// How many times the trace set is replayed against the same
    /// (unflushed) memory system — round 2 is the warm-state check.
    pub rounds: usize,
    /// Per-thread access runs (`runs[t]` is thread `t`'s trace).
    pub runs: Vec<Vec<RunCase>>,
}

impl TraceCase {
    /// Draw a complete case.
    pub fn generate(rng: &mut Prng) -> TraceCase {
        let nodes = if rng.chance(0.7) { 2 } else { 1 };
        let threads = rng.range(1, 5);
        let thread_nodes = (0..threads).map(|_| rng.range(0, nodes)).collect();
        let node_map = *rng.pick(&[
            NodeMap::Zero,
            NodeMap::PageMod,
            NodeMap::PageXorToucher,
            NodeMap::HighBit,
        ]);
        let rounds = if rng.chance(0.3) { 2 } else { 1 };
        let mut bases = Vec::new();
        let runs = (0..threads)
            .map(|_| (0..rng.range(1, 7)).map(|_| RunCase::generate(rng, &mut bases)).collect())
            .collect();
        TraceCase { geometry: GeometryCase::generate(rng), nodes, thread_nodes, node_map, rounds, runs }
    }

    /// Build the simulator traces (one per thread).
    pub fn traces(&self) -> Vec<Trace> {
        self.runs
            .iter()
            .map(|runs| {
                let mut t = Trace::new();
                for r in runs {
                    t.push(r.to_run());
                }
                t
            })
            .collect()
    }

    /// Thread count.
    pub fn threads(&self) -> usize {
        self.runs.len()
    }

    /// Re-clamp every run and structural field into the simulator
    /// contract (used after shrinking mutations and corpus loads).
    pub fn sanitize(&mut self) {
        if self.runs.is_empty() {
            self.runs.push(vec![RunCase { base: 0, stride: 64, count: 1, size: 64, kind: AccessKind::Load }]);
        }
        for runs in &mut self.runs {
            if runs.is_empty() {
                runs.push(RunCase { base: 0, stride: 64, count: 1, size: 64, kind: AccessKind::Load });
            }
            for r in runs.iter_mut() {
                r.sanitize();
            }
        }
        self.nodes = self.nodes.clamp(1, 2);
        self.rounds = self.rounds.clamp(1, 2);
        self.thread_nodes.resize(self.runs.len(), 0);
        for n in &mut self.thread_nodes {
            *n = (*n).min(self.nodes - 1);
        }
    }

    /// Serialize for the corpus.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("geometry", self.geometry.to_json()),
            ("nodes", Json::num(self.nodes as f64)),
            (
                "thread_nodes",
                Json::arr(self.thread_nodes.iter().map(|n| Json::num(*n as f64)).collect()),
            ),
            ("node_map", Json::str(self.node_map.label())),
            ("rounds", Json::num(self.rounds as f64)),
            (
                "threads",
                Json::arr(
                    self.runs
                        .iter()
                        .map(|runs| Json::arr(runs.iter().map(|r| r.to_json()).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Restore from the corpus form (sanitized on load).
    pub fn from_json(v: &Json) -> Result<TraceCase> {
        let runs = v
            .expect("threads")?
            .as_arr()?
            .iter()
            .map(|t| t.as_arr()?.iter().map(RunCase::from_json).collect::<Result<Vec<_>>>())
            .collect::<Result<Vec<_>>>()?;
        let mut case = TraceCase {
            geometry: GeometryCase::from_json(v.expect("geometry")?)?,
            nodes: u64_field(v, "nodes")? as usize,
            thread_nodes: v
                .expect("thread_nodes")?
                .as_arr()?
                .iter()
                .map(|n| Ok(n.as_f64()? as usize))
                .collect::<Result<Vec<_>>>()?,
            node_map: NodeMap::parse(v.expect("node_map")?.as_str()?)?,
            rounds: u64_field(v, "rounds")? as usize,
            runs,
        };
        if case.threads() > 64 {
            bail!("trace case has too many threads: {}", case.threads());
        }
        case.sanitize();
        Ok(case)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_cases_roundtrip_and_respect_bounds() {
        let mut rng = Prng::new(7);
        for _ in 0..64 {
            let case = TraceCase::generate(&mut rng);
            assert!((1..=4).contains(&case.threads()));
            assert!(case.thread_nodes.iter().all(|n| *n < case.nodes));
            for runs in &case.runs {
                for r in runs {
                    assert!(r.count >= 1);
                    // Descending runs must not wrap below address zero.
                    if r.stride < 0 {
                        assert!(r.base >= r.stride.unsigned_abs() * (r.count - 1));
                    }
                }
            }
            let back = TraceCase::from_json(&case.to_json()).unwrap();
            assert_eq!(back, case);
        }
    }

    #[test]
    fn node_map_is_pure_and_in_range() {
        let maps = [NodeMap::Zero, NodeMap::PageMod, NodeMap::PageXorToucher, NodeMap::HighBit];
        for map in maps {
            for addr in [0u64, 4096, 1 << 28, (1 << 32) - 64] {
                for toucher in 0..4 {
                    let a = map.node_of(2, addr, toucher);
                    assert!(a < 2);
                    assert_eq!(a, map.node_of(2, addr, toucher));
                    assert_eq!(map.node_of(1, addr, toucher), 0);
                }
            }
            assert_eq!(NodeMap::parse(map.label()).unwrap(), map);
        }
    }

    #[test]
    fn sanitize_repairs_hostile_corpus_values() {
        let mut case = TraceCase {
            geometry: GeometryCase {
                l1_sets: 1,
                l1_ways: 1,
                l2_sets: 1,
                l2_ways: 1,
                llc_sets: 1,
                llc_ways: 1,
                prefetch: false,
            },
            nodes: 9,
            thread_nodes: vec![5],
            node_map: NodeMap::PageMod,
            rounds: 0,
            runs: vec![vec![RunCase {
                base: u64::MAX,
                stride: -1_000_000,
                count: 0,
                size: 0,
                kind: AccessKind::Store,
            }]],
        };
        case.sanitize();
        assert_eq!(case.nodes, 2);
        assert_eq!(case.thread_nodes, vec![1]);
        assert_eq!(case.rounds, 1);
        let r = case.runs[0][0];
        assert_eq!(r.count, 1);
        assert!(r.size >= 1);
        assert!(r.stride >= -65536);
        assert!(r.base < BASE_SPAN + 65536 * 4096);
    }

    #[test]
    fn sanitized_runs_satisfy_the_no_wrap_contract() {
        // Worst-case hostile inputs across the clamp boundaries: after
        // sanitize, every run must pass the `AccessRun::no_wrap` check
        // that `Trace::push` debug-asserts (a sanitized case that trips
        // the assert would make the fuzzer abort instead of fuzz).
        let hostile = [
            (u64::MAX, i64::MIN, u64::MAX, 0u32),
            (u64::MAX, i64::MAX, u64::MAX, u32::MAX),
            (0, -65536, 4096, 64),          // max descending reach from zero
            (BASE_SPAN - 1, 65536, 4096, 64), // max ascending reach
            (0, 0, 0, 0),
        ];
        for (base, stride, count, size) in hostile {
            let mut r = RunCase { base, stride, count, size, kind: AccessKind::Load };
            r.sanitize();
            assert!(
                r.to_run().no_wrap(),
                "sanitized run violates the no-wrap contract: {r:?}"
            );
        }
    }
}
