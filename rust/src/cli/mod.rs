//! Declarative command-line parsing.
//!
//! `clap` is unavailable in the offline build environment, so this module
//! provides a small substitute: subcommands, `--flag value` / `--flag=value`
//! options, boolean switches, positional arguments and generated help text.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    /// Option name as typed after `--`.
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Boolean switch (no value) vs valued option.
    pub takes_value: bool,
    /// Default value applied when the option is absent.
    pub default: Option<&'static str>,
}

/// Specification of a subcommand.
#[derive(Clone, Debug)]
pub struct CmdSpec {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Options and switches the subcommand accepts.
    pub opts: Vec<OptSpec>,
    /// Positional arguments as (name, help) pairs, in order.
    pub positional: Vec<(&'static str, &'static str)>,
}

/// Top-level application spec.
#[derive(Clone, Debug)]
pub struct AppSpec {
    /// Binary name, used in usage strings.
    pub name: &'static str,
    /// One-line application description.
    pub about: &'static str,
    /// Version reported by `--version`.
    pub version: &'static str,
    /// Every subcommand, in help order.
    pub commands: Vec<CmdSpec>,
}

/// Parsed invocation.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    /// The matched subcommand name.
    pub command: String,
    /// Valued options (defaults already applied).
    pub opts: BTreeMap<String, String>,
    /// Boolean switches present on the command line.
    pub switches: Vec<String>,
    /// Positional arguments, in order.
    pub positional: Vec<String>,
}

impl Parsed {
    /// Valued option (or its default).
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Valued option parsed as `T`.
    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow!("invalid value '{s}' for --{name}")),
        }
    }

    /// Boolean switch presence.
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

impl AppSpec {
    /// Parse argv (excluding the program name). Returns `Err` with the help
    /// text embedded for `--help`/missing-command cases.
    pub fn parse(&self, argv: &[String]) -> Result<Parsed> {
        if argv.is_empty() {
            bail!("{}", self.help_text(None));
        }
        if argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            bail!("{}", self.help_text(None));
        }
        if argv[0] == "--version" || argv[0] == "-V" {
            bail!("{} {}", self.name, self.version);
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == argv[0])
            .ok_or_else(|| {
                anyhow!(
                    "unknown command '{}'\n\n{}",
                    argv[0],
                    self.help_text(None)
                )
            })?;

        let mut parsed = Parsed {
            command: cmd.name.to_string(),
            ..Default::default()
        };
        // Apply defaults first.
        for opt in &cmd.opts {
            if let (true, Some(d)) = (opt.takes_value, opt.default) {
                parsed.opts.insert(opt.name.to_string(), d.to_string());
            }
        }

        let mut i = 1;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                bail!("{}", self.help_text(Some(cmd)));
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = cmd
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| anyhow!("unknown option '--{name}' for '{}'", cmd.name))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(|| anyhow!("--{name} requires a value"))?
                                .clone()
                        }
                    };
                    parsed.opts.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        bail!("switch --{name} does not take a value");
                    }
                    parsed.switches.push(name.to_string());
                }
            } else {
                parsed.positional.push(arg.clone());
            }
            i += 1;
        }

        if parsed.positional.len() > cmd.positional.len() {
            bail!(
                "too many positional arguments for '{}' (expected at most {})",
                cmd.name,
                cmd.positional.len()
            );
        }
        Ok(parsed)
    }

    /// Render help: app-level or command-level.
    pub fn help_text(&self, cmd: Option<&CmdSpec>) -> String {
        let mut out = String::new();
        match cmd {
            None => {
                out.push_str(&format!("{} {} — {}\n\n", self.name, self.version, self.about));
                out.push_str(&format!("USAGE: {} <command> [options]\n\nCOMMANDS:\n", self.name));
                for c in &self.commands {
                    out.push_str(&format!("  {:<14} {}\n", c.name, c.help));
                }
                out.push_str("\nRun '");
                out.push_str(self.name);
                out.push_str(" <command> --help' for command options.\n");
            }
            Some(c) => {
                out.push_str(&format!("{} {} — {}\n\nUSAGE: {} {}", self.name, self.version, c.help, self.name, c.name));
                for (p, _) in &c.positional {
                    out.push_str(&format!(" <{p}>"));
                }
                out.push_str(" [options]\n");
                if !c.positional.is_empty() {
                    out.push_str("\nARGS:\n");
                    for (p, h) in &c.positional {
                        out.push_str(&format!("  {p:<14} {h}\n"));
                    }
                }
                if !c.opts.is_empty() {
                    out.push_str("\nOPTIONS:\n");
                    for o in &c.opts {
                        let mut left = format!("--{}", o.name);
                        if o.takes_value {
                            left.push_str(" <v>");
                        }
                        let default = o
                            .default
                            .map(|d| format!(" [default: {d}]"))
                            .unwrap_or_default();
                        out.push_str(&format!("  {left:<22} {}{default}\n", o.help));
                    }
                }
            }
        }
        out
    }
}

/// Shorthand constructors.
pub fn opt(name: &'static str, help: &'static str, default: Option<&'static str>) -> OptSpec {
    OptSpec { name, help, takes_value: true, default }
}

/// Shorthand for a boolean switch spec.
pub fn switch(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, help, takes_value: false, default: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> AppSpec {
        AppSpec {
            name: "dlroofline",
            about: "roofline repro",
            version: "0.1.0",
            commands: vec![
                CmdSpec {
                    name: "figure",
                    help: "reproduce a paper figure",
                    opts: vec![
                        opt("out", "output dir", Some("reports")),
                        opt("batch", "batch size", None),
                        switch("full-size", "use the paper's full sizes"),
                    ],
                    positional: vec![("id", "figure id, e.g. f3")],
                },
                CmdSpec {
                    name: "list",
                    help: "list experiments",
                    opts: vec![],
                    positional: vec![],
                },
            ],
        }
    }

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_positional() {
        let p = app()
            .parse(&argv(&["figure", "f3", "--batch", "32", "--full-size"]))
            .unwrap();
        assert_eq!(p.command, "figure");
        assert_eq!(p.positional, vec!["f3"]);
        assert_eq!(p.opt("batch"), Some("32"));
        assert!(p.has("full-size"));
        // default applied
        assert_eq!(p.opt("out"), Some("reports"));
    }

    #[test]
    fn equals_form() {
        let p = app().parse(&argv(&["figure", "f6", "--batch=64"])).unwrap();
        assert_eq!(p.opt_parse::<usize>("batch").unwrap(), Some(64));
    }

    #[test]
    fn unknown_command_errors_with_help() {
        let err = app().parse(&argv(&["bogus"])).unwrap_err().to_string();
        assert!(err.contains("unknown command"), "{err}");
        assert!(err.contains("COMMANDS"), "{err}");
    }

    #[test]
    fn unknown_option_errors() {
        let err = app().parse(&argv(&["figure", "--nope"])).unwrap_err().to_string();
        assert!(err.contains("--nope"), "{err}");
    }

    #[test]
    fn missing_value_errors() {
        let err = app().parse(&argv(&["figure", "--batch"])).unwrap_err().to_string();
        assert!(err.contains("requires a value"), "{err}");
    }

    #[test]
    fn too_many_positionals() {
        let err = app().parse(&argv(&["list", "x"])).unwrap_err().to_string();
        assert!(err.contains("too many positional"), "{err}");
    }

    #[test]
    fn help_flags_bail_with_usage() {
        let err = app().parse(&argv(&["--help"])).unwrap_err().to_string();
        assert!(err.contains("USAGE"), "{err}");
        let err = app().parse(&argv(&["figure", "--help"])).unwrap_err().to_string();
        assert!(err.contains("--full-size"), "{err}");
    }

    #[test]
    fn bad_parse_type() {
        let p = app().parse(&argv(&["figure", "--batch", "zz"])).unwrap();
        assert!(p.opt_parse::<usize>("batch").is_err());
    }
}
