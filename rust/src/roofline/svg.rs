//! SVG roofline figures — publication-style output for `reports/`.
//!
//! Hierarchical models render one diagonal roof per memory level (the
//! "roofline per level set" presentation of arXiv 2009.05257): the DRAM
//! roof is the solid black paper roofline, cache-level roofs are grey
//! dashed diagonals, and each kernel point is re-plotted at its
//! per-level arithmetic intensity with smaller markers.

use super::model::{MemLevel, RooflineModel};
use super::point::KernelPoint;

const W: f64 = 760.0;
const H: f64 = 520.0;
const ML: f64 = 70.0; // margins
const MR: f64 = 30.0;
const MT: f64 = 40.0;
const MB: f64 = 60.0;

const COLORS: &[&str] = &["#d62728", "#1f77b4", "#2ca02c", "#9467bd", "#ff7f0e", "#8c564b"];

/// Render a complete SVG document for one roofline + points.
pub fn svg_plot(roofline: &RooflineModel, points: &[KernelPoint]) -> String {
    let ridge = roofline.ridge();
    let mut finite: Vec<f64> = points
        .iter()
        .map(|p| p.ai())
        .filter(|x| x.is_finite() && *x > 0.0)
        .collect();
    // Cache-level AIs widen the x-range too (they sit left of the DRAM
    // AI when a level moves more bytes). Only the levels that get echo
    // markers below count — the DRAM split AIs are never drawn.
    for p in points {
        for level in [MemLevel::L1, MemLevel::L2, MemLevel::Llc] {
            if let Some(ai) = p.ai_at(level) {
                if ai.is_finite() && ai > 0.0 {
                    finite.push(ai);
                }
            }
        }
    }
    let ai_min = finite.iter().fold(ridge / 64.0, |a, &b| a.min(b / 2.0)).max(1e-3);
    let ai_max = finite.iter().fold(ridge * 8.0, |a, &b| a.max(b * 2.0));
    let peak = roofline.peak();
    let p_min = points
        .iter()
        .map(|p| p.perf())
        .fold(peak / 3000.0, f64::min)
        .max(peak / 1e5)
        / 2.0;
    let p_max = peak * 2.0;

    let (lx0, lx1) = (ai_min.log10(), ai_max.log10());
    let (ly0, ly1) = (p_min.log10(), p_max.log10());
    let x = |ai: f64| ML + (ai.log10() - lx0) / (lx1 - lx0) * (W - ML - MR);
    let y = |p: f64| H - MB - (p.max(1.0).log10() - ly0) / (ly1 - ly0) * (H - MT - MB);

    let mut s = String::new();
    s.push_str(&format!(
        r##"<svg xmlns="http://www.w3.org/2000/svg" width="{W}" height="{H}" viewBox="0 0 {W} {H}">"##
    ));
    s.push_str(&format!(
        r##"<rect width="{W}" height="{H}" fill="white"/>
<text x="{}" y="24" font-family="sans-serif" font-size="15" text-anchor="middle">{}</text>"##,
        W / 2.0,
        xml_escape(&roofline.name)
    ));

    // Axes.
    s.push_str(&format!(
        r##"<line x1="{ML}" y1="{}" x2="{}" y2="{}" stroke="black"/>
<line x1="{ML}" y1="{MT}" x2="{ML}" y2="{}" stroke="black"/>"##,
        H - MB,
        W - MR,
        H - MB,
        H - MB
    ));
    // Log grid + labels.
    let mut dec = lx0.ceil() as i32;
    while (dec as f64) <= lx1 {
        let ai = 10f64.powi(dec);
        s.push_str(&format!(
            r##"<line x1="{0}" y1="{MT}" x2="{0}" y2="{1}" stroke="#eee"/>
<text x="{0}" y="{2}" font-family="sans-serif" font-size="11" text-anchor="middle">{3}</text>"##,
            x(ai),
            H - MB,
            H - MB + 18.0,
            format_pow(dec)
        ));
        dec += 1;
    }
    let mut dec = ly0.ceil() as i32;
    while (dec as f64) <= ly1 {
        let p = 10f64.powi(dec);
        s.push_str(&format!(
            r##"<line x1="{ML}" y1="{0}" x2="{1}" y2="{0}" stroke="#eee"/>
<text x="{2}" y="{3}" font-family="sans-serif" font-size="11" text-anchor="end">{4}</text>"##,
            y(p),
            W - MR,
            ML - 6.0,
            y(p) + 4.0,
            format_pow(dec)
        ));
        dec += 1;
    }
    s.push_str(&format!(
        r##"<text x="{}" y="{}" font-family="sans-serif" font-size="13" text-anchor="middle">arithmetic intensity (FLOP/byte)</text>
<text x="16" y="{}" font-family="sans-serif" font-size="13" text-anchor="middle" transform="rotate(-90 16 {})">performance (FLOP/s)</text>"##,
        (ML + W - MR) / 2.0,
        H - 14.0,
        H / 2.0,
        H / 2.0
    ));

    // One diagonal roof per memory level above the DRAM roof, grey and
    // dashed, clipped at the compute peak.
    for roof in &roofline.roofs {
        if roof.level == MemLevel::DramLocal {
            continue; // drawn as the solid paper roofline below
        }
        let beta = roof.bytes_per_sec;
        let ai_ridge = (peak / beta).clamp(ai_min, ai_max);
        let (color, dash) = match roof.level {
            MemLevel::DramRemote => ("#b22", "8 4"),
            _ => ("#999", "5 4"),
        };
        s.push_str(&format!(
            r##"<polyline fill="none" stroke="{color}" stroke-dasharray="{dash}" points="{:.1},{:.1} {:.1},{:.1}"/>
<text x="{:.1}" y="{:.1}" font-family="sans-serif" font-size="10" fill="{color}">{}</text>"##,
            x(ai_min),
            y(roofline.peak().min(ai_min * beta)),
            x(ai_ridge),
            y(roofline.peak().min(ai_ridge * beta)),
            x(ai_ridge) + 3.0,
            y(roofline.peak().min(ai_ridge * beta)) - 4.0,
            xml_escape(roof.level.label())
        ));
    }

    // The paper's DRAM roofline: diagonal to the ridge, flat after.
    s.push_str(&format!(
        r##"<polyline fill="none" stroke="black" stroke-width="2" points="{:.1},{:.1} {:.1},{:.1} {:.1},{:.1}"/>"##,
        x(ai_min),
        y(roofline.attainable(ai_min)),
        x(ridge.clamp(ai_min, ai_max)),
        y(peak),
        x(ai_max),
        y(peak)
    ));
    // Secondary ceilings, dashed.
    for c in &roofline.ceilings[..roofline.ceilings.len().saturating_sub(1)] {
        let ai_start = (c.flops_per_sec / roofline.bandwidth()).max(ai_min);
        s.push_str(&format!(
            r##"<line x1="{:.1}" y1="{:.1}" x2="{:.1}" y2="{:.1}" stroke="#777" stroke-dasharray="6 4"/>
<text x="{:.1}" y="{:.1}" font-family="sans-serif" font-size="11" fill="#555">{}</text>"##,
            x(ai_start),
            y(c.flops_per_sec),
            x(ai_max),
            y(c.flops_per_sec),
            x(ai_start) + 4.0,
            y(c.flops_per_sec) - 5.0,
            xml_escape(&c.label)
        ));
    }

    // Points + vertical dashed AI lines (the paper's presentation). A
    // point with a level breakdown is echoed at each level's AI with a
    // small hollow marker — its walk across the level set.
    for (i, p) in points.iter().enumerate() {
        let color = COLORS[i % COLORS.len()];
        let ai = if p.ai().is_finite() { p.ai() } else { ai_max };
        for level in MemLevel::all() {
            if level == MemLevel::DramLocal || level == MemLevel::DramRemote {
                continue; // the DRAM marker is the main (filled) one
            }
            if let Some(lai) = p.ai_at(level) {
                if lai.is_finite() && lai > 0.0 {
                    s.push_str(&format!(
                        r##"<circle cx="{:.1}" cy="{:.1}" r="3" fill="none" stroke="{color}"/>"##,
                        x(lai.clamp(ai_min, ai_max)),
                        y(p.perf()),
                    ));
                }
            }
        }
        s.push_str(&format!(
            r##"<line x1="{0:.1}" y1="{MT}" x2="{0:.1}" y2="{1}" stroke="{color}" stroke-dasharray="3 5" opacity="0.6"/>
<circle cx="{0:.1}" cy="{2:.1}" r="5" fill="{color}"/>
<text x="{3:.1}" y="{4:.1}" font-family="sans-serif" font-size="12" fill="{color}">{5}</text>"##,
            x(ai),
            H - MB,
            y(p.perf()),
            x(ai) + 8.0,
            y(p.perf()) - 6.0,
            xml_escape(&format!("{} {}", p.name, p.note))
        ));
    }
    s.push_str("</svg>\n");
    s
}

fn format_pow(dec: i32) -> String {
    format!("1e{dec}")
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roofline::model::Ceiling;
    use crate::roofline::point::LevelBytes;

    #[test]
    fn svg_well_formed_ish() {
        let r = RooflineModel::new(
            "svg test <xeon>",
            vec![
                Ceiling { label: "scalar".into(), flops_per_sec: 10e9 },
                Ceiling { label: "AVX-512 FMA".into(), flops_per_sec: 102.4e9 },
            ],
            20e9,
            "DRAM",
        );
        let pts = vec![
            KernelPoint::new("conv", 1e9, 2e8, 0.02).with_note("cold"),
            KernelPoint::new("gelu", 1e8, 2e9, 0.3),
        ];
        let svg = svg_plot(&r, &pts);
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>\n"));
        assert!(svg.contains("polyline"));
        assert_eq!(svg.matches("<circle").count(), 2);
        // Escaped title.
        assert!(svg.contains("&lt;xeon&gt;"));
        assert!(!svg.contains("<xeon>"));
        // Balanced-ish tags: every <text has a </text>.
        assert_eq!(svg.matches("<text").count(), svg.matches("</text>").count());
    }

    #[test]
    fn empty_points_still_draws_roof() {
        let r = RooflineModel::new(
            "empty",
            vec![Ceiling { label: "peak".into(), flops_per_sec: 1e12 }],
            100e9,
            "DRAM",
        );
        let svg = svg_plot(&r, &[]);
        assert!(svg.contains("polyline"));
    }

    #[test]
    fn hierarchical_model_draws_level_roofs_and_markers() {
        let m = crate::sim::machine::MachineConfig::xeon_6248();
        let r = RooflineModel::for_machine(&m, 1, 1, "single-thread");
        let p = KernelPoint::new("gelu", 1e9, 5e8, 0.05).with_levels(LevelBytes {
            l1: 1e9,
            l2: 8e8,
            llc: 6e8,
            dram_local: 5e8,
            dram_remote: 0.0,
        });
        let svg = svg_plot(&r, &[p]);
        // Level labels on the grey roofs.
        for label in ["L1", "L2", "LLC", "DRAM-remote"] {
            assert!(svg.contains(&format!(">{label}</text>")), "missing {label} roof");
        }
        // One filled DRAM marker + three hollow level echoes.
        assert_eq!(svg.matches("<circle").count(), 4);
        assert_eq!(svg.matches(r#"r="3" fill="none""#).count(), 3);
    }
}
