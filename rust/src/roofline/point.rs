//! A kernel's point on the roofline: (W, Q, R) → (I, P, utilisation).

use super::model::RooflineModel;

/// One measured kernel on one roofline.
#[derive(Clone, Debug)]
pub struct KernelPoint {
    pub name: String,
    /// Work W (FLOPs, PMU-derived).
    pub work_flops: f64,
    /// Traffic Q (bytes, IMC-derived).
    pub traffic_bytes: f64,
    /// Runtime R (seconds).
    pub runtime: f64,
    /// Optional annotation, e.g. "cold caches".
    pub note: String,
}

impl KernelPoint {
    pub fn new(name: &str, work_flops: f64, traffic_bytes: f64, runtime: f64) -> KernelPoint {
        assert!(work_flops >= 0.0 && traffic_bytes >= 0.0 && runtime > 0.0);
        KernelPoint {
            name: name.to_string(),
            work_flops,
            traffic_bytes,
            runtime,
            note: String::new(),
        }
    }

    pub fn with_note(mut self, note: &str) -> KernelPoint {
        self.note = note.to_string();
        self
    }

    /// Arithmetic intensity I = W / Q.
    pub fn ai(&self) -> f64 {
        if self.traffic_bytes == 0.0 {
            f64::INFINITY
        } else {
            self.work_flops / self.traffic_bytes
        }
    }

    /// Achieved performance P = W / R.
    pub fn perf(&self) -> f64 {
        self.work_flops / self.runtime
    }

    /// Utilisation of peak compute π (the paper's "runtime compute" %).
    pub fn utilization(&self, roofline: &RooflineModel) -> f64 {
        self.perf() / roofline.peak()
    }

    /// Fraction of the *attainable* roof at this AI — 1.0 means the point
    /// sits on the roofline.
    pub fn roof_fraction(&self, roofline: &RooflineModel) -> f64 {
        let roof = roofline.attainable(self.ai());
        if roof == 0.0 {
            0.0
        } else {
            self.perf() / roof
        }
    }

    /// Achieved bandwidth Q / R.
    pub fn bandwidth(&self) -> f64 {
        self.traffic_bytes / self.runtime
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roofline::model::Ceiling;

    fn roofline() -> RooflineModel {
        RooflineModel::new(
            "t",
            vec![Ceiling { label: "peak".into(), flops_per_sec: 100e9 }],
            10e9,
            "DRAM",
        )
    }

    #[test]
    fn derived_quantities() {
        // 1 GFLOP over 0.5 GB in 20 ms: AI = 2, P = 50 GFLOP/s.
        let p = KernelPoint::new("k", 1e9, 0.5e9, 0.02);
        assert_eq!(p.ai(), 2.0);
        assert_eq!(p.perf(), 50e9);
        assert_eq!(p.utilization(&roofline()), 0.5);
        // Roof at AI=2 is min(100, 2·10)=20 GFLOP/s… perf 50 > roof is
        // impossible physically, fraction reports it honestly (>1 flags
        // a measurement problem — the paper hit this with single-thread
        // prefetcher bandwidth, §2.2).
        assert!((p.roof_fraction(&roofline()) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn infinite_ai_when_no_traffic() {
        let p = KernelPoint::new("warm", 1e9, 0.0, 0.01);
        assert!(p.ai().is_infinite());
        assert_eq!(p.perf(), 1e11);
    }

    #[test]
    fn bandwidth_derivation() {
        let p = KernelPoint::new("k", 1.0, 1e9, 0.1);
        assert_eq!(p.bandwidth(), 10e9);
    }

    #[test]
    #[should_panic]
    fn zero_runtime_rejected() {
        KernelPoint::new("bad", 1.0, 1.0, 0.0);
    }
}
