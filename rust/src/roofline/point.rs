//! A kernel's point on the roofline: (W, Q, R) → (I, P, utilisation),
//! plus the per-memory-level traffic that gives the hierarchical model
//! one arithmetic intensity per level (AI_L1 … AI_DRAM).

use super::model::{Binding, MemLevel, RooflineModel};
use crate::sim::hierarchy::TrafficStats;

/// Bytes moved at each memory level for one kernel execution — the
/// per-level Q the hierarchical roofline divides W by.
///
/// Levels are *boundaries*: `l1` is core↔L1 traffic (demand accesses
/// plus NT-store lines), `l2` is what crossed the L1↔L2 boundary (L1
/// fills + L1 dirty writebacks), `llc` the L2↔LLC boundary, and the two
/// DRAM entries attribute every IMC line — reads, NT stores and victim
/// writebacks — to its owning node. The DRAM entries therefore sum
/// exactly to the paper's IMC-counted Q.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LevelBytes {
    /// Core-L1 boundary bytes.
    pub l1: f64,
    /// L1-L2 boundary bytes.
    pub l2: f64,
    /// L2-LLC boundary bytes.
    pub llc: f64,
    /// IMC bytes served by the requesting thread's own node.
    pub dram_local: f64,
    /// IMC bytes served cross-node (UPI-crossing).
    pub dram_remote: f64,
}

impl LevelBytes {
    /// Derive the per-level breakdown from simulated traffic stats.
    pub fn from_traffic(t: &TrafficStats) -> LevelBytes {
        LevelBytes {
            l1: t.l1_bytes() as f64,
            l2: t.l2_bytes() as f64,
            llc: t.llc_bytes() as f64,
            dram_local: t.dram_local_bytes(),
            dram_remote: t.dram_remote_bytes(),
        }
    }

    /// Bytes at one level.
    pub fn get(&self, level: MemLevel) -> f64 {
        match level {
            MemLevel::L1 => self.l1,
            MemLevel::L2 => self.l2,
            MemLevel::Llc => self.llc,
            MemLevel::DramLocal => self.dram_local,
            MemLevel::DramRemote => self.dram_remote,
        }
    }

    /// Total DRAM bytes (local + remote) — the IMC-counted Q.
    pub fn dram(&self) -> f64 {
        self.dram_local + self.dram_remote
    }
}

/// One measured kernel on one roofline.
#[derive(Clone, Debug)]
pub struct KernelPoint {
    /// Kernel display name.
    pub name: String,
    /// Work W (FLOPs, PMU-derived).
    pub work_flops: f64,
    /// Traffic Q (bytes, IMC-derived).
    pub traffic_bytes: f64,
    /// Runtime R (seconds).
    pub runtime: f64,
    /// Optional annotation, e.g. "cold caches".
    pub note: String,
    /// Per-memory-level traffic, when the measurement carried it.
    pub levels: Option<LevelBytes>,
}

impl KernelPoint {
    /// Point from W (FLOPs), Q (bytes) and R (seconds).
    pub fn new(name: &str, work_flops: f64, traffic_bytes: f64, runtime: f64) -> KernelPoint {
        assert!(work_flops >= 0.0 && traffic_bytes >= 0.0 && runtime > 0.0);
        KernelPoint {
            name: name.to_string(),
            work_flops,
            traffic_bytes,
            runtime,
            note: String::new(),
            levels: None,
        }
    }

    /// Attach an annotation (builder style).
    pub fn with_note(mut self, note: &str) -> KernelPoint {
        self.note = note.to_string();
        self
    }

    /// Attach the per-level traffic breakdown.
    pub fn with_levels(mut self, levels: LevelBytes) -> KernelPoint {
        self.levels = Some(levels);
        self
    }

    /// Arithmetic intensity I = W / Q (DRAM, the paper's definition).
    pub fn ai(&self) -> f64 {
        if self.traffic_bytes == 0.0 {
            f64::INFINITY
        } else {
            self.work_flops / self.traffic_bytes
        }
    }

    /// Per-level arithmetic intensity AI_level = W / Q_level. `None` when
    /// the point carries no per-level breakdown; infinite when the kernel
    /// moved no bytes through that level.
    pub fn ai_at(&self, level: MemLevel) -> Option<f64> {
        let levels = self.levels.as_ref()?;
        let bytes = levels.get(level);
        Some(if bytes <= 0.0 { f64::INFINITY } else { self.work_flops / bytes })
    }

    /// Which roof binds this point in the hierarchical model. Falls back
    /// to the DRAM view (memory vs compute) when the point carries no
    /// per-level breakdown.
    pub fn binding(&self, roofline: &RooflineModel) -> Binding {
        match &self.levels {
            Some(levels) => roofline.binding(self.work_flops, levels),
            None => {
                if self.ai().is_finite() && roofline.memory_bound(self.ai()) {
                    Binding::Level(crate::roofline::model::MemLevel::DramLocal)
                } else {
                    Binding::Compute
                }
            }
        }
    }

    /// Achieved performance P = W / R.
    pub fn perf(&self) -> f64 {
        self.work_flops / self.runtime
    }

    /// Utilisation of peak compute π (the paper's "runtime compute" %).
    pub fn utilization(&self, roofline: &RooflineModel) -> f64 {
        self.perf() / roofline.peak()
    }

    /// Fraction of the *attainable* roof at this AI — 1.0 means the point
    /// sits on the roofline.
    pub fn roof_fraction(&self, roofline: &RooflineModel) -> f64 {
        let roof = roofline.attainable(self.ai());
        if roof == 0.0 {
            0.0
        } else {
            self.perf() / roof
        }
    }

    /// Achieved bandwidth Q / R.
    pub fn bandwidth(&self) -> f64 {
        self.traffic_bytes / self.runtime
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roofline::model::Ceiling;

    fn roofline() -> RooflineModel {
        RooflineModel::new(
            "t",
            vec![Ceiling { label: "peak".into(), flops_per_sec: 100e9 }],
            10e9,
            "DRAM",
        )
    }

    #[test]
    fn derived_quantities() {
        // 1 GFLOP over 0.5 GB in 20 ms: AI = 2, P = 50 GFLOP/s.
        let p = KernelPoint::new("k", 1e9, 0.5e9, 0.02);
        assert_eq!(p.ai(), 2.0);
        assert_eq!(p.perf(), 50e9);
        assert_eq!(p.utilization(&roofline()), 0.5);
        // Roof at AI=2 is min(100, 2·10)=20 GFLOP/s… perf 50 > roof is
        // impossible physically, fraction reports it honestly (>1 flags
        // a measurement problem — the paper hit this with single-thread
        // prefetcher bandwidth, §2.2).
        assert!((p.roof_fraction(&roofline()) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn infinite_ai_when_no_traffic() {
        let p = KernelPoint::new("warm", 1e9, 0.0, 0.01);
        assert!(p.ai().is_infinite());
        assert_eq!(p.perf(), 1e11);
    }

    #[test]
    fn bandwidth_derivation() {
        let p = KernelPoint::new("k", 1.0, 1e9, 0.1);
        assert_eq!(p.bandwidth(), 10e9);
    }

    #[test]
    fn per_level_ai() {
        let levels = LevelBytes {
            l1: 4e9,
            l2: 2e9,
            llc: 1e9,
            dram_local: 0.5e9,
            dram_remote: 0.0,
        };
        let p = KernelPoint::new("k", 1e9, 0.5e9, 0.02).with_levels(levels);
        assert_eq!(p.ai_at(MemLevel::L1), Some(0.25));
        assert_eq!(p.ai_at(MemLevel::L2), Some(0.5));
        assert_eq!(p.ai_at(MemLevel::Llc), Some(1.0));
        assert_eq!(p.ai_at(MemLevel::DramLocal), Some(2.0));
        // No remote bytes → infinite AI, that roof can never bind.
        assert_eq!(p.ai_at(MemLevel::DramRemote), Some(f64::INFINITY));
        // AI at the whole-DRAM level matches the flat ai().
        assert_eq!(p.ai(), p.work_flops / levels.dram());
    }

    #[test]
    fn ai_at_none_without_levels() {
        let p = KernelPoint::new("k", 1.0, 1.0, 1.0);
        assert_eq!(p.ai_at(MemLevel::L1), None);
    }

    #[test]
    fn binding_falls_back_to_dram_view() {
        let r = roofline(); // ridge at 10
        let mem = KernelPoint::new("m", 1e9, 1e9, 0.1); // AI 1 < 10
        assert_eq!(mem.binding(&r), Binding::Level(MemLevel::DramLocal));
        let comp = KernelPoint::new("c", 1e12, 1e9, 0.1); // AI 1000
        assert_eq!(comp.binding(&r), Binding::Compute);
    }

    #[test]
    #[should_panic]
    fn zero_runtime_rejected() {
        KernelPoint::new("bad", 1.0, 1.0, 0.0);
    }
}
