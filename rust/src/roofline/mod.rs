//! The Roofline model itself: `P = min(π, I·β)` (the paper's §1 formula),
//! with multiple compute ceilings (scalar / AVX2 / AVX-512-FMA — the
//! "possible gains from vectorisation" rooflines), kernel points, plots
//! (ASCII and SVG) and paper-style reports.

pub mod model;
pub mod plot;
pub mod point;
pub mod report;
pub mod svg;

pub use model::{Binding, Ceiling, LevelRoof, MemLevel, RooflineModel};
pub use point::{KernelPoint, LevelBytes};
