//! Log-log ASCII roofline plots for terminal reports — the repo's
//! equivalent of the paper's Figures 1 and 3–8.

use super::model::RooflineModel;
use super::point::KernelPoint;
use crate::util::human::fmt_flops;

/// Plot geometry.
const WIDTH: usize = 72;
const HEIGHT: usize = 22;

/// Render a roofline with kernel points as ASCII art.
///
/// X: log10(AI) over a range covering all points and the ridge;
/// Y: log10(FLOP/s) from ~3.5 decades below peak to just above it.
pub fn ascii_plot(roofline: &RooflineModel, points: &[KernelPoint]) -> String {
    let ridge = roofline.ridge();
    let finite_ais: Vec<f64> = points
        .iter()
        .map(|p| p.ai())
        .filter(|ai| ai.is_finite() && *ai > 0.0)
        .collect();
    let ai_min = finite_ais
        .iter()
        .fold(ridge / 64.0, |a, &b| a.min(b / 2.0))
        .max(1e-3);
    let ai_max = finite_ais
        .iter()
        .fold(ridge * 8.0, |a, &b| a.max(b * 2.0));
    let (lx0, lx1) = (ai_min.log10(), ai_max.log10());

    let peak = roofline.peak();
    let perf_min = points
        .iter()
        .map(|p| p.perf())
        .fold(peak / 3000.0, f64::min)
        .max(peak / 1e5);
    let (ly0, ly1) = ((perf_min / 2.0).log10(), (peak * 2.0).log10());

    let x_of = |ai: f64| -> usize {
        let t = (ai.log10() - lx0) / (lx1 - lx0);
        ((t * (WIDTH - 1) as f64).round() as isize).clamp(0, WIDTH as isize - 1) as usize
    };
    let y_of = |perf: f64| -> usize {
        let t = (perf.max(1.0).log10() - ly0) / (ly1 - ly0);
        let row = ((1.0 - t) * (HEIGHT - 1) as f64).round() as isize;
        row.clamp(0, HEIGHT as isize - 1) as usize
    };

    let mut grid = vec![vec![' '; WIDTH]; HEIGHT];

    // Draw the roof: for each column, attainable P at that AI.
    for col in 0..WIDTH {
        let ai = 10f64.powf(lx0 + (lx1 - lx0) * col as f64 / (WIDTH - 1) as f64);
        let p = roofline.attainable(ai);
        let row = y_of(p);
        grid[row][col] = if roofline.memory_bound(ai) { '/' } else { '-' };
    }
    // Secondary ceilings as dotted lines in the compute-bound region.
    for c in &roofline.ceilings[..roofline.ceilings.len() - 1] {
        let row = y_of(c.flops_per_sec);
        for col in 0..WIDTH {
            let ai = 10f64.powf(lx0 + (lx1 - lx0) * col as f64 / (WIDTH - 1) as f64);
            if ai * roofline.bandwidth() >= c.flops_per_sec && grid[row][col] == ' ' {
                grid[row][col] = '.';
            }
        }
    }

    // Points: label with letters.
    let mut legend = String::new();
    for (i, p) in points.iter().enumerate() {
        let marker = (b'A' + (i % 26) as u8) as char;
        let ai = if p.ai().is_finite() { p.ai() } else { ai_max };
        let row = y_of(p.perf());
        let col = x_of(ai);
        grid[row][col] = marker;
        legend.push_str(&format!(
            "  {marker}: {:<28} AI={:<9.3} P={:<16} {}\n",
            p.name,
            p.ai(),
            fmt_flops(p.perf()),
            p.note
        ));
    }

    let mut out = String::new();
    out.push_str(&format!(
        "roofline: {}   π={}  β={}  ridge AI={:.2}\n",
        roofline.name,
        fmt_flops(peak),
        crate::util::human::fmt_rate(roofline.bandwidth()),
        ridge
    ));
    if roofline.roofs.len() > 1 {
        let levels: Vec<String> = roofline
            .roofs
            .iter()
            .map(|r| {
                format!("{}={}", r.level.label(), crate::util::human::fmt_rate(r.bytes_per_sec))
            })
            .collect();
        out.push_str(&format!("level roofs: {}\n", levels.join("  ")));
    }
    out.push_str(&format!("{:>14} ┐\n", fmt_flops(10f64.powf(ly1))));
    for row in grid {
        out.push_str("               │");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>14} └{}\n",
        fmt_flops(10f64.powf(ly0)),
        "─".repeat(WIDTH)
    ));
    out.push_str(&format!(
        "               AI {:.3} … {:.1} FLOP/byte (log)\n",
        ai_min, ai_max
    ));
    out.push_str(&legend);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roofline::model::Ceiling;

    fn roofline() -> RooflineModel {
        RooflineModel::new(
            "unit",
            vec![
                Ceiling { label: "scalar".into(), flops_per_sec: 10e9 },
                Ceiling { label: "AVX-512 FMA".into(), flops_per_sec: 100e9 },
            ],
            20e9,
            "DRAM",
        )
    }

    #[test]
    fn plot_contains_roof_and_points() {
        let points = vec![
            KernelPoint::new("compute-ish", 1e9, 1e8, 0.02).with_note("cold"),
            KernelPoint::new("memory-ish", 1e8, 1e9, 0.1),
        ];
        let s = ascii_plot(&roofline(), &points);
        assert!(s.contains('/'), "diagonal roof missing");
        assert!(s.contains('-'), "flat roof missing");
        assert!(s.contains('A') && s.contains('B'), "points missing");
        assert!(s.contains("compute-ish"));
        assert!(s.contains("ridge AI=5.00"));
        assert!(s.contains("cold"));
    }

    #[test]
    fn handles_infinite_ai() {
        let points = vec![KernelPoint::new("warm", 1e9, 0.0, 0.05)];
        let s = ascii_plot(&roofline(), &points);
        assert!(s.contains("warm"));
        assert!(s.contains("inf") || s.contains("AI=inf"));
    }

    #[test]
    fn empty_points_ok() {
        let s = ascii_plot(&roofline(), &[]);
        assert!(s.contains("roofline: unit"));
        // A single-roof (paper-style) model needs no level legend.
        assert!(!s.contains("level roofs:"));
    }

    #[test]
    fn hierarchical_roofline_lists_level_roofs() {
        let m = crate::sim::machine::MachineConfig::xeon_6248();
        let r = RooflineModel::for_machine(&m, 1, 1, "single-thread");
        let s = ascii_plot(&r, &[]);
        assert!(s.contains("level roofs:"), "{s}");
        for label in ["L1=", "L2=", "LLC=", "DRAM-local=", "DRAM-remote="] {
            assert!(s.contains(label), "missing {label}");
        }
    }
}
