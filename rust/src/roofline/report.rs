//! Paper-style tabular reports: per-kernel W/Q/R/AI/P/utilisation rows
//! with per-level arithmetic intensities and the binding roof,
//! paper-vs-measured comparison, markdown and CSV output.

use super::model::{MemLevel, RooflineModel};
use super::point::KernelPoint;
use crate::util::human::{fmt_bytes, fmt_flops, fmt_pct, fmt_rate, fmt_seconds};

/// Expected utilisation (and optionally the binding memory level) from
/// the paper for comparison rows.
#[derive(Clone, Debug)]
pub struct PaperExpectation {
    /// Kernel name the expectation applies to.
    pub kernel: String,
    /// The paper's reported utilisation of peak (0–1), if given.
    pub utilization: Option<f64>,
    /// Free-text of what the paper claims (orderings etc.).
    pub claim: String,
    /// Expected binding roof in the hierarchical model, if the claim
    /// names one (e.g. "gelu is DRAM-bound").
    pub bound: Option<MemLevel>,
}

fn fmt_ai(ai: f64) -> String {
    if ai.is_finite() {
        format!("{ai:.3}")
    } else {
        "∞".into()
    }
}

fn fmt_ai_opt(ai: Option<f64>) -> String {
    match ai {
        Some(ai) => fmt_ai(ai),
        None => "—".into(),
    }
}

/// Render a markdown table for points on a roofline.
pub fn markdown_table(roofline: &RooflineModel, points: &[KernelPoint]) -> String {
    let mut out = String::new();
    let betas: Vec<String> = roofline
        .roofs
        .iter()
        .map(|r| format!("β_{} = {}", r.level.label(), fmt_rate(r.bytes_per_sec)))
        .collect();
    out.push_str(&format!(
        "### {} — π = {}, {}, DRAM ridge = {:.2} FLOP/byte\n\n",
        roofline.name,
        fmt_flops(roofline.peak()),
        betas.join(", "),
        roofline.ridge()
    ));
    out.push_str(
        "| kernel | W | Q | R | AI_L1 | AI_L2 | AI_LLC | AI (DRAM) | P | util π | roof frac | bound |\n\
         |---|---|---|---|---|---|---|---|---|---|---|---|\n",
    );
    for p in points {
        out.push_str(&format!(
            "| {}{} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {:.2} | {} |\n",
            p.name,
            if p.note.is_empty() { String::new() } else { format!(" ({})", p.note) },
            fmt_flops_amount(p.work_flops),
            fmt_bytes(p.traffic_bytes),
            fmt_seconds(p.runtime),
            fmt_ai_opt(p.ai_at(MemLevel::L1)),
            fmt_ai_opt(p.ai_at(MemLevel::L2)),
            fmt_ai_opt(p.ai_at(MemLevel::Llc)),
            fmt_ai(p.ai()),
            fmt_flops(p.perf()),
            fmt_pct(p.utilization(roofline)),
            p.roof_fraction(roofline),
            p.binding(roofline).label()
        ));
    }
    out.push('\n');
    out
}

/// Paper-vs-measured comparison table.
pub fn comparison_table(
    roofline: &RooflineModel,
    points: &[KernelPoint],
    expectations: &[PaperExpectation],
) -> String {
    let mut out = String::from(
        "| kernel | paper util | measured util | Δ (pp) | bound | paper claim |\n\
         |---|---|---|---|---|---|\n",
    );
    for e in expectations {
        // Prefer the cold-cache cell: expectations (and any pinned
        // binding level) describe the cold measurement, and cold/warm
        // points share a kernel name.
        let measured = points
            .iter()
            .find(|p| p.name == e.kernel && p.note == "cold")
            .or_else(|| points.iter().find(|p| p.name == e.kernel));
        let m_util = measured.map(|p| p.utilization(roofline));
        let (paper_s, meas_s, delta_s) = match (e.utilization, m_util) {
            (Some(pu), Some(mu)) => (
                fmt_pct(pu),
                fmt_pct(mu),
                format!("{:+.1}", (mu - pu) * 100.0),
            ),
            (None, Some(mu)) => ("—".into(), fmt_pct(mu), "—".into()),
            (Some(pu), None) => (fmt_pct(pu), "missing".into(), "—".into()),
            (None, None) => ("—".into(), "missing".into(), "—".into()),
        };
        let bound_s = match (e.bound, measured) {
            (Some(expected), Some(p)) => {
                let got = p.binding(roofline);
                let ok = got == super::model::Binding::Level(expected);
                format!(
                    "{} (expected {}) {}",
                    got.label(),
                    expected.label(),
                    if ok { "✓" } else { "✗" }
                )
            }
            (None, Some(p)) => p.binding(roofline).label().to_string(),
            (_, None) => "—".into(),
        };
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} |\n",
            e.kernel, paper_s, meas_s, delta_s, bound_s, e.claim
        ));
    }
    out.push('\n');
    out
}

/// CSV rows for machine consumption. Per-level AI columns are empty when
/// a point carries no level breakdown.
pub fn csv(roofline: &RooflineModel, points: &[KernelPoint]) -> String {
    let mut out = String::from(
        "roofline,kernel,note,work_flops,traffic_bytes,runtime_s,ai,perf_flops,util,\
         ai_l1,ai_l2,ai_llc,ai_dram_local,ai_dram_remote,bound\n",
    );
    let csv_ai = |ai: Option<f64>| -> String {
        match ai {
            Some(ai) if ai.is_finite() => format!("{ai:.6}"),
            Some(_) => "inf".into(),
            None => String::new(),
        }
    };
    for p in points {
        out.push_str(&format!(
            "{},{},{},{:.0},{:.0},{:.9},{},{:.0},{:.6},{},{},{},{},{},{}\n",
            roofline.name,
            p.name,
            p.note,
            p.work_flops,
            p.traffic_bytes,
            p.runtime,
            csv_ai(Some(p.ai())),
            p.perf(),
            p.utilization(roofline),
            csv_ai(p.ai_at(MemLevel::L1)),
            csv_ai(p.ai_at(MemLevel::L2)),
            csv_ai(p.ai_at(MemLevel::Llc)),
            csv_ai(p.ai_at(MemLevel::DramLocal)),
            csv_ai(p.ai_at(MemLevel::DramRemote)),
            p.binding(roofline).label(),
        ));
    }
    out
}

fn fmt_flops_amount(flops: f64) -> String {
    crate::util::human::fmt_si(flops, "FLOP")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roofline::model::Ceiling;
    use crate::roofline::point::LevelBytes;

    fn setup() -> (RooflineModel, Vec<KernelPoint>) {
        let r = RooflineModel::new(
            "t",
            vec![Ceiling { label: "peak".into(), flops_per_sec: 100e9 }],
            10e9,
            "DRAM",
        );
        let pts = vec![
            KernelPoint::new("conv_nchw16c", 1e9, 5e7, 0.0115).with_note("cold"),
            KernelPoint::new("gelu", 1e8, 1e9, 0.15),
        ];
        (r, pts)
    }

    #[test]
    fn markdown_has_all_rows() {
        let (r, pts) = setup();
        let md = markdown_table(&r, &pts);
        assert!(md.contains("conv_nchw16c"));
        assert!(md.contains("(cold)"));
        assert!(md.contains("gelu"));
        assert!(md.contains("| kernel |"));
        // gelu at AI 0.1 is memory-bound; conv at 20 is compute-bound.
        assert!(md.contains("DRAM-local"));
        assert!(md.contains("compute"));
        // The header names every roof the model carries.
        assert!(md.contains("β_DRAM-local"));
    }

    #[test]
    fn markdown_shows_per_level_ai() {
        let (r, mut pts) = setup();
        pts[1] = pts[1].clone().with_levels(LevelBytes {
            l1: 4e9,
            l2: 2e9,
            llc: 1e9,
            dram_local: 1e9,
            dram_remote: 0.0,
        });
        let md = markdown_table(&r, &pts);
        assert!(md.contains("0.025"), "AI_L1 = 1e8/4e9 missing: {md}");
        assert!(md.contains("0.100"), "AI_LLC missing");
        // Points without levels render em-dashes, not zeroes.
        assert!(md.contains("—"));
    }

    #[test]
    fn comparison_marks_deltas() {
        let (r, pts) = setup();
        let exp = vec![
            PaperExpectation {
                kernel: "conv_nchw16c".into(),
                utilization: Some(0.867),
                claim: "highest of the three".into(),
                bound: None,
            },
            PaperExpectation {
                kernel: "missing_kernel".into(),
                utilization: Some(0.1),
                claim: "".into(),
                bound: None,
            },
        ];
        let md = comparison_table(&r, &pts, &exp);
        assert!(md.contains("86.7%"));
        assert!(md.contains("missing"));
        assert!(md.contains("Δ"));
    }

    #[test]
    fn comparison_checks_expected_binding() {
        let (r, pts) = setup();
        let exp = vec![
            PaperExpectation {
                kernel: "gelu".into(),
                utilization: None,
                claim: "memory-bound".into(),
                bound: Some(MemLevel::DramLocal),
            },
            PaperExpectation {
                kernel: "conv_nchw16c".into(),
                utilization: None,
                claim: "compute-bound".into(),
                bound: Some(MemLevel::DramLocal),
            },
        ];
        let md = comparison_table(&r, &pts, &exp);
        // gelu (AI 0.1, ridge 10) matches DRAM-local; conv (AI 20) is
        // compute-bound and mismatches.
        assert!(md.contains("✓"), "{md}");
        assert!(md.contains("✗"), "{md}");
    }

    #[test]
    fn csv_parses_back() {
        let (r, pts) = setup();
        let text = csv(&r, &pts);
        assert_eq!(text.lines().count(), 3);
        let row: Vec<&str> = text.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(row[1], "conv_nchw16c");
        assert!(row[3].parse::<f64>().is_ok());
        assert_eq!(row.len(), 15);
        // No level breakdown → empty per-level AI cells.
        assert_eq!(row[9], "");
        assert_eq!(row.last().unwrap(), &"compute");
    }
}
