//! Paper-style tabular reports: per-kernel W/Q/R/AI/P/utilisation rows,
//! paper-vs-measured comparison, markdown and CSV output.

use super::model::RooflineModel;
use super::point::KernelPoint;
use crate::util::human::{fmt_bytes, fmt_flops, fmt_pct, fmt_seconds};

/// Expected utilisation from the paper for comparison rows.
#[derive(Clone, Debug)]
pub struct PaperExpectation {
    pub kernel: String,
    /// The paper's reported utilisation of peak (0–1), if given.
    pub utilization: Option<f64>,
    /// Free-text of what the paper claims (orderings etc.).
    pub claim: String,
}

/// Render a markdown table for points on a roofline.
pub fn markdown_table(roofline: &RooflineModel, points: &[KernelPoint]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "### {} — π = {}, β = {}, ridge = {:.2} FLOP/byte\n\n",
        roofline.name,
        fmt_flops(roofline.peak()),
        crate::util::human::fmt_rate(roofline.bandwidth),
        roofline.ridge()
    ));
    out.push_str(
        "| kernel | W | Q | R | AI (FLOP/B) | P | util π | roof frac | bound |\n\
         |---|---|---|---|---|---|---|---|---|\n",
    );
    for p in points {
        let ai = p.ai();
        let bound = if ai.is_finite() && roofline.memory_bound(ai) { "memory" } else { "compute" };
        out.push_str(&format!(
            "| {}{} | {} | {} | {} | {} | {} | {} | {:.2} | {} |\n",
            p.name,
            if p.note.is_empty() { String::new() } else { format!(" ({})", p.note) },
            fmt_flops_amount(p.work_flops),
            fmt_bytes(p.traffic_bytes),
            fmt_seconds(p.runtime),
            if ai.is_finite() { format!("{ai:.3}") } else { "∞".into() },
            fmt_flops(p.perf()),
            fmt_pct(p.utilization(roofline)),
            p.roof_fraction(roofline),
            bound
        ));
    }
    out.push('\n');
    out
}

/// Paper-vs-measured comparison table.
pub fn comparison_table(
    roofline: &RooflineModel,
    points: &[KernelPoint],
    expectations: &[PaperExpectation],
) -> String {
    let mut out = String::from(
        "| kernel | paper util | measured util | Δ (pp) | paper claim |\n|---|---|---|---|---|\n",
    );
    for e in expectations {
        let measured = points.iter().find(|p| p.name == e.kernel);
        let m_util = measured.map(|p| p.utilization(roofline));
        let (paper_s, meas_s, delta_s) = match (e.utilization, m_util) {
            (Some(pu), Some(mu)) => (
                fmt_pct(pu),
                fmt_pct(mu),
                format!("{:+.1}", (mu - pu) * 100.0),
            ),
            (None, Some(mu)) => ("—".into(), fmt_pct(mu), "—".into()),
            (Some(pu), None) => (fmt_pct(pu), "missing".into(), "—".into()),
            (None, None) => ("—".into(), "missing".into(), "—".into()),
        };
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} |\n",
            e.kernel, paper_s, meas_s, delta_s, e.claim
        ));
    }
    out.push('\n');
    out
}

/// CSV rows for machine consumption.
pub fn csv(roofline: &RooflineModel, points: &[KernelPoint]) -> String {
    let mut out =
        String::from("roofline,kernel,note,work_flops,traffic_bytes,runtime_s,ai,perf_flops,util\n");
    for p in points {
        out.push_str(&format!(
            "{},{},{},{:.0},{:.0},{:.9},{},{:.0},{:.6}\n",
            roofline.name,
            p.name,
            p.note,
            p.work_flops,
            p.traffic_bytes,
            p.runtime,
            if p.ai().is_finite() { format!("{:.6}", p.ai()) } else { "inf".into() },
            p.perf(),
            p.utilization(roofline),
        ));
    }
    out
}

fn fmt_flops_amount(flops: f64) -> String {
    crate::util::human::fmt_si(flops, "FLOP")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roofline::model::Ceiling;

    fn setup() -> (RooflineModel, Vec<KernelPoint>) {
        let r = RooflineModel::new(
            "t",
            vec![Ceiling { label: "peak".into(), flops_per_sec: 100e9 }],
            10e9,
            "DRAM",
        );
        let pts = vec![
            KernelPoint::new("conv_nchw16c", 1e9, 5e7, 0.0115).with_note("cold"),
            KernelPoint::new("gelu", 1e8, 1e9, 0.15),
        ];
        (r, pts)
    }

    #[test]
    fn markdown_has_all_rows() {
        let (r, pts) = setup();
        let md = markdown_table(&r, &pts);
        assert!(md.contains("conv_nchw16c"));
        assert!(md.contains("(cold)"));
        assert!(md.contains("gelu"));
        assert!(md.contains("| kernel |"));
        // gelu at AI 0.1 is memory-bound; conv at 20 is compute-bound.
        assert!(md.contains("memory"));
        assert!(md.contains("compute"));
    }

    #[test]
    fn comparison_marks_deltas() {
        let (r, pts) = setup();
        let exp = vec![
            PaperExpectation {
                kernel: "conv_nchw16c".into(),
                utilization: Some(0.867),
                claim: "highest of the three".into(),
            },
            PaperExpectation {
                kernel: "missing_kernel".into(),
                utilization: Some(0.1),
                claim: "".into(),
            },
        ];
        let md = comparison_table(&r, &pts, &exp);
        assert!(md.contains("86.7%"));
        assert!(md.contains("missing"));
        assert!(md.contains("Δ"));
    }

    #[test]
    fn csv_parses_back() {
        let (r, pts) = setup();
        let text = csv(&r, &pts);
        assert_eq!(text.lines().count(), 3);
        let row: Vec<&str> = text.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(row[1], "conv_nchw16c");
        assert!(row[3].parse::<f64>().is_ok());
    }
}
