//! Roofline model assembly: π ceilings and the hierarchical β roofs.
//!
//! The paper's model has a single β (DRAM, counted at the IMC). The
//! hierarchical extension (arXiv 2009.05257, 2009.04598) adds one roof
//! per memory level — L1, L2, LLC, local DRAM, remote DRAM — each with
//! its own bandwidth and its own arithmetic intensity for a given
//! kernel. The DRAM-local projection of the hierarchical model reduces
//! *exactly* to the paper's single-β model: [`RooflineModel::attainable`],
//! [`RooflineModel::ridge`] and [`RooflineModel::memory_bound`] keep
//! their original (DRAM-β) semantics, while [`RooflineModel::attainable_hier`]
//! takes the min over every level roof.
//!
//! ```
//! use dlroofline::roofline::model::{Ceiling, RooflineModel};
//!
//! // The paper's Fig 1 shape: π = 100 GFLOP/s over a 10 GB/s DRAM β.
//! let roofline = RooflineModel::new(
//!     "example",
//!     vec![Ceiling { label: "peak".into(), flops_per_sec: 100e9 }],
//!     10e9,
//!     "DRAM",
//! );
//! // The ridge sits at π/β = 10 FLOP/byte.
//! assert_eq!(roofline.ridge(), 10.0);
//! // Left of the ridge performance is β·AI, right of it π.
//! assert_eq!(roofline.attainable(2.0), 2.0 * 10e9);
//! assert_eq!(roofline.attainable(40.0), 100e9);
//! assert!(roofline.memory_bound(2.0) && !roofline.memory_bound(40.0));
//! ```

use crate::sim::core::VecWidth;
use crate::sim::machine::MachineConfig;

use super::point::LevelBytes;

/// One level of the memory hierarchy, shallowest first. The ordering is
/// the hierarchy depth: data that reaches a deeper level crossed every
/// shallower one.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum MemLevel {
    /// Per-core L1 data cache.
    L1,
    /// Per-core L2 cache.
    L2,
    /// Per-socket shared last-level cache.
    Llc,
    /// DRAM behind the IMCs of the node(s) the scenario binds to.
    DramLocal,
    /// DRAM reached across the UPI link (cross-socket).
    DramRemote,
}

impl MemLevel {
    /// Every level, shallowest first.
    pub fn all() -> [MemLevel; 5] {
        [
            MemLevel::L1,
            MemLevel::L2,
            MemLevel::Llc,
            MemLevel::DramLocal,
            MemLevel::DramRemote,
        ]
    }

    /// Short display label.
    pub fn label(&self) -> &'static str {
        match self {
            MemLevel::L1 => "L1",
            MemLevel::L2 => "L2",
            MemLevel::Llc => "LLC",
            MemLevel::DramLocal => "DRAM-local",
            MemLevel::DramRemote => "DRAM-remote",
        }
    }
}

/// One horizontal compute ceiling (e.g. "AVX-512 FMA", "AVX2", "scalar").
#[derive(Clone, Debug, PartialEq)]
pub struct Ceiling {
    /// Display label, e.g. `AVX-512 FMA`.
    pub label: String,
    /// Ceiling height (FLOP/s).
    pub flops_per_sec: f64,
}

/// One diagonal bandwidth roof: the peak byte rate of one memory level.
#[derive(Clone, Debug, PartialEq)]
pub struct LevelRoof {
    /// Which memory level the roof belongs to.
    pub level: MemLevel,
    /// β for this level (bytes/s).
    pub bytes_per_sec: f64,
    /// Display label, e.g. `DRAM 1 node`.
    pub label: String,
}

/// Which roof binds a kernel in the hierarchical model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Binding {
    /// The top compute ceiling π.
    Compute,
    /// A memory-level roof.
    Level(MemLevel),
}

impl Binding {
    /// Short display label (`compute` or the level's label).
    pub fn label(&self) -> &'static str {
        match self {
            Binding::Compute => "compute",
            Binding::Level(l) => l.label(),
        }
    }
}

/// A roofline for one platform × one resource scenario: compute ceilings
/// plus an ordered set of per-memory-level bandwidth roofs.
#[derive(Clone, Debug)]
pub struct RooflineModel {
    /// e.g. `xeon_6248 / single-thread`.
    pub name: String,
    /// Compute ceilings, ascending; the last is the peak π.
    pub ceilings: Vec<Ceiling>,
    /// Bandwidth roofs, ordered shallowest level first. Always non-empty;
    /// the paper's single-β model is the one-roof (DRAM-local) case.
    pub roofs: Vec<LevelRoof>,
}

impl RooflineModel {
    /// Build the paper's single-β model: one DRAM roof. Ceilings are
    /// sorted ascending (NaN-safe `total_cmp`); all rates must be finite
    /// and positive.
    pub fn new(name: &str, ceilings: Vec<Ceiling>, bandwidth: f64, bandwidth_label: &str) -> Self {
        RooflineModel::with_roofs(
            name,
            ceilings,
            vec![LevelRoof {
                level: MemLevel::DramLocal,
                bytes_per_sec: bandwidth,
                label: bandwidth_label.to_string(),
            }],
        )
    }

    /// Build a hierarchical model from measured/modelled peaks.
    pub fn with_roofs(name: &str, mut ceilings: Vec<Ceiling>, mut roofs: Vec<LevelRoof>) -> Self {
        assert!(!ceilings.is_empty(), "need at least one ceiling");
        assert!(!roofs.is_empty(), "need at least one level roof");
        for c in &ceilings {
            assert!(
                c.flops_per_sec.is_finite() && c.flops_per_sec > 0.0,
                "ceiling '{}' must be finite and positive, got {}",
                c.label,
                c.flops_per_sec
            );
        }
        for r in &roofs {
            assert!(
                r.bytes_per_sec.is_finite() && r.bytes_per_sec > 0.0,
                "{} roof '{}' must be finite and positive, got {}",
                r.level.label(),
                r.label,
                r.bytes_per_sec
            );
        }
        ceilings.sort_by(|a, b| a.flops_per_sec.total_cmp(&b.flops_per_sec));
        roofs.sort_by_key(|r| r.level);
        RooflineModel { name: name.to_string(), ceilings, roofs }
    }

    /// Build the full hierarchical roofline for a simulated machine
    /// scenario: three cache-level roofs derived from core geometry, the
    /// paper's DRAM (NT-stream) roof, and — on multi-socket machines — a
    /// UPI-limited remote-DRAM roof.
    pub fn for_machine(
        config: &MachineConfig,
        threads: usize,
        nodes_used: usize,
        label: &str,
    ) -> Self {
        let ceilings = vec![
            Ceiling {
                label: "scalar".into(),
                flops_per_sec: config.peak_flops(threads, VecWidth::Scalar),
            },
            Ceiling {
                label: "AVX2 FMA".into(),
                flops_per_sec: config.peak_flops(threads, VecWidth::V256),
            },
            Ceiling {
                label: "AVX-512 FMA".into(),
                flops_per_sec: config.peak_flops(threads, VecWidth::V512),
            },
        ];
        let mut roofs = vec![
            LevelRoof {
                level: MemLevel::L1,
                bytes_per_sec: config.peak_l1_bw(threads),
                label: "L1 (load ports)".into(),
            },
            LevelRoof {
                level: MemLevel::L2,
                bytes_per_sec: config.peak_l2_bw(threads),
                label: "L2 stream".into(),
            },
            LevelRoof {
                level: MemLevel::Llc,
                bytes_per_sec: config.peak_llc_bw(threads),
                label: "LLC stream".into(),
            },
            LevelRoof {
                level: MemLevel::DramLocal,
                bytes_per_sec: config.peak_bw(threads, nodes_used),
                label: "DRAM (NT-stream)".into(),
            },
        ];
        if config.sockets > 1 {
            roofs.push(LevelRoof {
                level: MemLevel::DramRemote,
                bytes_per_sec: config.peak_remote_bw(threads),
                label: "DRAM remote (UPI)".into(),
            });
        }
        RooflineModel::with_roofs(&format!("{} / {}", config.name, label), ceilings, roofs)
    }

    /// Peak compute π (the top ceiling).
    pub fn peak(&self) -> f64 {
        self.ceilings.last().unwrap().flops_per_sec
    }

    /// The DRAM roof — the paper's β. Falls back to the deepest roof for
    /// models without an explicit DRAM level.
    pub fn dram_roof(&self) -> &LevelRoof {
        self.roofs
            .iter()
            .find(|r| r.level == MemLevel::DramLocal)
            .unwrap_or_else(|| self.roofs.last().unwrap())
    }

    /// The paper's single β (bytes/s): the DRAM-local roof.
    pub fn bandwidth(&self) -> f64 {
        self.dram_roof().bytes_per_sec
    }

    /// Label of the DRAM roof.
    pub fn bandwidth_label(&self) -> &str {
        &self.dram_roof().label
    }

    /// The roof for a specific level, if the model carries one.
    pub fn roof(&self, level: MemLevel) -> Option<&LevelRoof> {
        self.roofs.iter().find(|r| r.level == level)
    }

    /// The paper's equation: attainable P at DRAM arithmetic intensity
    /// `ai`. This is the single-β (DRAM) projection of the hierarchical
    /// model — numerically identical to the pre-hierarchy model.
    pub fn attainable(&self, ai: f64) -> f64 {
        assert!(ai >= 0.0);
        self.peak().min(ai * self.bandwidth())
    }

    /// Hierarchical attainable: the min over the compute peak and every
    /// level roof evaluated at that level's own arithmetic intensity
    /// (`work / levels.get(level)`). Levels the kernel moved no bytes
    /// through do not bind. Returns the bound and which roof set it;
    /// ties go to the shallower roof, compute winning exact ties.
    pub fn attainable_hier(&self, work_flops: f64, levels: &LevelBytes) -> (f64, Binding) {
        let mut best = self.peak();
        let mut binding = Binding::Compute;
        for roof in &self.roofs {
            let bytes = levels.get(roof.level);
            if bytes <= 0.0 {
                continue;
            }
            let p = work_flops / bytes * roof.bytes_per_sec;
            if p < best {
                best = p;
                binding = Binding::Level(roof.level);
            }
        }
        (best, binding)
    }

    /// Which roof binds a kernel with the given per-level traffic.
    pub fn binding(&self, work_flops: f64, levels: &LevelBytes) -> Binding {
        self.attainable_hier(work_flops, levels).1
    }

    /// Attainable P under a specific ceiling (e.g. what a scalar kernel
    /// could at best reach), against the DRAM roof.
    pub fn attainable_under(&self, ai: f64, ceiling_label: &str) -> Option<f64> {
        self.ceilings
            .iter()
            .find(|c| c.label == ceiling_label)
            .map(|c| c.flops_per_sec.min(ai * self.bandwidth()))
    }

    /// The ridge point I* = π/β of the DRAM roof: the AI where the kernel
    /// stops being memory-bound. The paper's §3.1.2 observation — moving
    /// from one thread to a socket moves the ridge right — falls out of
    /// this.
    pub fn ridge(&self) -> f64 {
        self.peak() / self.bandwidth()
    }

    /// Is a kernel at DRAM AI `ai` memory-bound on this platform?
    pub fn memory_bound(&self, ai: f64) -> bool {
        ai < self.ridge()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple() -> RooflineModel {
        RooflineModel::new(
            "test",
            vec![
                Ceiling { label: "scalar".into(), flops_per_sec: 1e11 },
                Ceiling { label: "AVX-512 FMA".into(), flops_per_sec: 1e12 },
            ],
            100e9,
            "DRAM",
        )
    }

    #[test]
    fn attainable_is_min_of_roofs() {
        let r = simple();
        // Memory-bound region: P = I·β.
        assert_eq!(r.attainable(1.0), 100e9);
        assert_eq!(r.attainable(5.0), 500e9);
        // Compute-bound region: P = π.
        assert_eq!(r.attainable(100.0), 1e12);
        // Exactly at the ridge.
        assert_eq!(r.attainable(r.ridge()), 1e12);
    }

    #[test]
    fn ridge_point() {
        let r = simple();
        assert_eq!(r.ridge(), 10.0);
        assert!(r.memory_bound(9.9));
        assert!(!r.memory_bound(10.1));
    }

    #[test]
    fn ceilings_sorted() {
        let r = RooflineModel::new(
            "t",
            vec![
                Ceiling { label: "big".into(), flops_per_sec: 5e12 },
                Ceiling { label: "small".into(), flops_per_sec: 1e11 },
            ],
            1e9,
            "x",
        );
        assert_eq!(r.peak(), 5e12);
        assert_eq!(r.ceilings[0].label, "small");
    }

    #[test]
    fn under_ceiling_lookup() {
        let r = simple();
        assert_eq!(r.attainable_under(100.0, "scalar"), Some(1e11));
        assert_eq!(r.attainable_under(0.5, "scalar"), Some(50e9));
        assert_eq!(r.attainable_under(1.0, "nope"), None);
    }

    #[test]
    fn machine_rooflines_scale_with_scenario() {
        let m = crate::sim::machine::MachineConfig::xeon_6248();
        let one = RooflineModel::for_machine(&m, 1, 1, "single-thread");
        let socket = RooflineModel::for_machine(&m, 20, 1, "one-socket");
        let two = RooflineModel::for_machine(&m, 40, 2, "two-socket");
        assert!(socket.peak() > 10.0 * one.peak());
        assert!((two.peak() / socket.peak() - 2.0).abs() < 1e-9);
        // Paper §3.1.2: the ridge moves right from 1 thread → socket
        // (bandwidth per thread shrinks).
        assert!(socket.ridge() > one.ridge());
    }

    #[test]
    fn machine_roofs_are_monotone_down_the_hierarchy() {
        let m = crate::sim::machine::MachineConfig::xeon_6248();
        for threads in [1usize, 10, 20, 40] {
            let r = RooflineModel::for_machine(&m, threads, 1, "t");
            let bw = |level| r.roof(level).unwrap().bytes_per_sec;
            assert!(bw(MemLevel::L1) > bw(MemLevel::L2));
            assert!(bw(MemLevel::L2) > bw(MemLevel::Llc));
            assert!(bw(MemLevel::Llc) > bw(MemLevel::DramLocal), "t={threads}");
            assert!(bw(MemLevel::DramLocal) > bw(MemLevel::DramRemote));
        }
    }

    #[test]
    fn single_socket_machine_has_no_remote_roof() {
        let m = crate::sim::machine::MachineConfig::xeon_6248_1s();
        let r = RooflineModel::for_machine(&m, 1, 1, "t");
        assert!(r.roof(MemLevel::DramRemote).is_none());
        assert!(r.roof(MemLevel::DramLocal).is_some());
    }

    #[test]
    fn dram_projection_matches_single_beta_model() {
        // The acceptance contract: the hierarchical model's DRAM view is
        // the old single-β model, point for point.
        let m = crate::sim::machine::MachineConfig::xeon_6248();
        let hier = RooflineModel::for_machine(&m, 20, 1, "one-socket");
        let flat = RooflineModel::new(
            &hier.name,
            hier.ceilings.clone(),
            m.peak_bw(20, 1),
            "DRAM (NT-stream)",
        );
        for ai in [0.01, 0.5, 2.0, 16.0, 1000.0] {
            assert_eq!(hier.attainable(ai), flat.attainable(ai));
        }
        assert_eq!(hier.ridge(), flat.ridge());
        assert_eq!(hier.bandwidth(), flat.bandwidth());
    }

    #[test]
    fn hier_attainable_binds_at_the_tightest_roof() {
        let m = crate::sim::machine::MachineConfig::xeon_6248();
        let r = RooflineModel::for_machine(&m, 1, 1, "single-thread");
        // All traffic at DRAM, AI = 1 → DRAM roof binds.
        let w = 1e9;
        let dram_heavy = LevelBytes {
            l1: w,
            l2: w,
            llc: w,
            dram_local: w,
            dram_remote: 0.0,
        };
        let (p, b) = r.attainable_hier(w, &dram_heavy);
        assert_eq!(b, Binding::Level(MemLevel::DramLocal));
        assert!((p - r.bandwidth()).abs() / p < 1e-12);
        // LLC-resident: no DRAM bytes → the LLC roof binds instead.
        let llc_resident = LevelBytes {
            l1: w,
            l2: w,
            llc: w,
            dram_local: 0.0,
            dram_remote: 0.0,
        };
        let (p2, b2) = r.attainable_hier(w, &llc_resident);
        assert_eq!(b2, Binding::Level(MemLevel::Llc));
        assert!(p2 > p, "LLC roof must sit above the DRAM roof");
        // No traffic anywhere → compute-bound at π.
        let silent = LevelBytes::default();
        let (p3, b3) = r.attainable_hier(w, &silent);
        assert_eq!(b3, Binding::Compute);
        assert_eq!(p3, r.peak());
    }

    #[test]
    #[should_panic]
    fn empty_ceilings_panic() {
        RooflineModel::new("x", vec![], 1.0, "b");
    }

    #[test]
    #[should_panic(expected = "finite and positive")]
    fn nan_ceiling_rejected() {
        RooflineModel::new(
            "x",
            vec![Ceiling { label: "nan".into(), flops_per_sec: f64::NAN }],
            1.0,
            "b",
        );
    }

    #[test]
    #[should_panic(expected = "finite and positive")]
    fn non_positive_bandwidth_rejected() {
        RooflineModel::new(
            "x",
            vec![Ceiling { label: "peak".into(), flops_per_sec: 1e9 }],
            0.0,
            "b",
        );
    }

    #[test]
    #[should_panic(expected = "finite and positive")]
    fn infinite_roof_rejected() {
        RooflineModel::with_roofs(
            "x",
            vec![Ceiling { label: "peak".into(), flops_per_sec: 1e9 }],
            vec![LevelRoof {
                level: MemLevel::L1,
                bytes_per_sec: f64::INFINITY,
                label: "bad".into(),
            }],
        );
    }

    #[test]
    fn mem_level_labels_distinct() {
        let labels: std::collections::HashSet<&str> =
            MemLevel::all().iter().map(|l| l.label()).collect();
        assert_eq!(labels.len(), MemLevel::all().len());
    }
}
