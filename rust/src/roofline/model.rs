//! Roofline model assembly: π ceilings and the β roof.

use crate::sim::core::VecWidth;
use crate::sim::machine::MachineConfig;

/// One horizontal compute ceiling (e.g. "AVX-512 FMA", "AVX2", "scalar").
#[derive(Clone, Debug, PartialEq)]
pub struct Ceiling {
    pub label: String,
    pub flops_per_sec: f64,
}

/// A roofline for one platform × one resource scenario.
#[derive(Clone, Debug)]
pub struct RooflineModel {
    /// e.g. `xeon_6248 / single-thread`.
    pub name: String,
    /// Compute ceilings, ascending; the last is the peak π.
    pub ceilings: Vec<Ceiling>,
    /// Peak memory bandwidth β (bytes/s).
    pub bandwidth: f64,
    pub bandwidth_label: String,
}

impl RooflineModel {
    /// Build from measured/modelled peaks. Ceilings are sorted ascending.
    pub fn new(name: &str, mut ceilings: Vec<Ceiling>, bandwidth: f64, bandwidth_label: &str) -> Self {
        assert!(!ceilings.is_empty(), "need at least one ceiling");
        assert!(bandwidth > 0.0);
        ceilings.sort_by(|a, b| a.flops_per_sec.partial_cmp(&b.flops_per_sec).unwrap());
        RooflineModel {
            name: name.to_string(),
            ceilings,
            bandwidth,
            bandwidth_label: bandwidth_label.to_string(),
        }
    }

    /// Build the paper-style roofline for a simulated machine scenario.
    pub fn for_machine(config: &MachineConfig, threads: usize, nodes_used: usize, label: &str) -> Self {
        let ceilings = vec![
            Ceiling {
                label: "scalar".into(),
                flops_per_sec: config.peak_flops(threads, VecWidth::Scalar),
            },
            Ceiling {
                label: "AVX2 FMA".into(),
                flops_per_sec: config.peak_flops(threads, VecWidth::V256),
            },
            Ceiling {
                label: "AVX-512 FMA".into(),
                flops_per_sec: config.peak_flops(threads, VecWidth::V512),
            },
        ];
        let bw = config.peak_bw(threads, nodes_used);
        RooflineModel::new(
            &format!("{} / {}", config.name, label),
            ceilings,
            bw,
            "DRAM (NT-stream)",
        )
    }

    /// Peak compute π (the top ceiling).
    pub fn peak(&self) -> f64 {
        self.ceilings.last().unwrap().flops_per_sec
    }

    /// The paper's equation: attainable P at arithmetic intensity `ai`.
    pub fn attainable(&self, ai: f64) -> f64 {
        assert!(ai >= 0.0);
        self.peak().min(ai * self.bandwidth)
    }

    /// Attainable P under a specific ceiling (e.g. what a scalar kernel
    /// could at best reach).
    pub fn attainable_under(&self, ai: f64, ceiling_label: &str) -> Option<f64> {
        self.ceilings
            .iter()
            .find(|c| c.label == ceiling_label)
            .map(|c| c.flops_per_sec.min(ai * self.bandwidth))
    }

    /// The ridge point I* = π/β: the AI where the kernel stops being
    /// memory-bound. The paper's §3.1.2 observation — moving from one
    /// thread to a socket moves the ridge right — falls out of this.
    pub fn ridge(&self) -> f64 {
        self.peak() / self.bandwidth
    }

    /// Is a kernel at `ai` memory-bound on this platform?
    pub fn memory_bound(&self, ai: f64) -> bool {
        ai < self.ridge()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple() -> RooflineModel {
        RooflineModel::new(
            "test",
            vec![
                Ceiling { label: "scalar".into(), flops_per_sec: 1e11 },
                Ceiling { label: "AVX-512 FMA".into(), flops_per_sec: 1e12 },
            ],
            100e9,
            "DRAM",
        )
    }

    #[test]
    fn attainable_is_min_of_roofs() {
        let r = simple();
        // Memory-bound region: P = I·β.
        assert_eq!(r.attainable(1.0), 100e9);
        assert_eq!(r.attainable(5.0), 500e9);
        // Compute-bound region: P = π.
        assert_eq!(r.attainable(100.0), 1e12);
        // Exactly at the ridge.
        assert_eq!(r.attainable(r.ridge()), 1e12);
    }

    #[test]
    fn ridge_point() {
        let r = simple();
        assert_eq!(r.ridge(), 10.0);
        assert!(r.memory_bound(9.9));
        assert!(!r.memory_bound(10.1));
    }

    #[test]
    fn ceilings_sorted() {
        let r = RooflineModel::new(
            "t",
            vec![
                Ceiling { label: "big".into(), flops_per_sec: 5e12 },
                Ceiling { label: "small".into(), flops_per_sec: 1e11 },
            ],
            1e9,
            "x",
        );
        assert_eq!(r.peak(), 5e12);
        assert_eq!(r.ceilings[0].label, "small");
    }

    #[test]
    fn under_ceiling_lookup() {
        let r = simple();
        assert_eq!(r.attainable_under(100.0, "scalar"), Some(1e11));
        assert_eq!(r.attainable_under(0.5, "scalar"), Some(50e9));
        assert_eq!(r.attainable_under(1.0, "nope"), None);
    }

    #[test]
    fn machine_rooflines_scale_with_scenario() {
        let m = crate::sim::machine::MachineConfig::xeon_6248();
        let one = RooflineModel::for_machine(&m, 1, 1, "single-thread");
        let socket = RooflineModel::for_machine(&m, 20, 1, "one-socket");
        let two = RooflineModel::for_machine(&m, 40, 2, "two-socket");
        assert!(socket.peak() > 10.0 * one.peak());
        assert!((two.peak() / socket.peak() - 2.0).abs() < 1e-9);
        // Paper §3.1.2: the ridge moves right from 1 thread → socket
        // (bandwidth per thread shrinks).
        assert!(socket.ridge() > one.ridge());
    }

    #[test]
    #[should_panic]
    fn empty_ceilings_panic() {
        RooflineModel::new("x", vec![], 1.0, "b");
    }
}
