//! Roofline-guided kernel autotuning (`dlroofline tune`).
//!
//! A [`TuningLattice`] expands a small set of kernel families into a
//! variant space — blocking factors, loop orders, data layouts and
//! software-prefetch distances ([`crate::kernels::VariantParams`]) —
//! and drives the whole lattice through the parallel, memoizing plan
//! executor ([`crate::coordinator::plan::execute_specs_with_budget`])
//! as one synthetic grid experiment. Every variant is an ordinary
//! measurement cell whose content hash folds in the knob values, so
//! with a persistent cell store (`--cache-dir`):
//!
//! * a **warm re-tune** of an unchanged lattice executes **zero
//!   simulations** and emits byte-identical reports, and
//! * a **lattice edit** re-simulates exactly the added variants — the
//!   incremental-sweep property of the cell cache, inherited for free.
//!
//! Ranking follows the hierarchical roofline (DESIGN.md §10): per
//! scenario and kernel family, variants are ordered by attainable
//! FLOP/s from [`crate::roofline::model::RooflineModel::attainable_hier`]
//! over the variant's *measured* per-level traffic (tie-break: measured
//! FLOP/s, then name — total and deterministic, so `--jobs N` and warm
//! re-tunes reproduce the ranking bit-for-bit). Each winner is
//! explained through its [`Binding`] level — e.g. a blocking factor
//! that moved a convolution from DRAM-bound to LLC-bound.

pub mod report;

use std::cmp::Ordering;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Result};

use crate::coordinator::manifest::{FileRecord, RunManifest};
use crate::coordinator::plan::{self, ExecutedCell, JobBudget, PlanStats, StoreUsage};
use crate::coordinator::store::CellStore;
use crate::harness::experiments::{roofline_for, ExperimentParams};
use crate::harness::spec::{ExperimentSpec, GridSpec, KernelSpec, SpecKind};
use crate::harness::{CacheState, ScenarioSpec};
use crate::kernels::{DataLayout, LoopOrder, TuneKernel, VariantParams, VariantSpec};
use crate::roofline::model::Binding;
use crate::util::fsutil::write_atomic;

/// The variant space `dlroofline tune` searches: the cross product of
/// every knob axis, canonicalised per kernel family (knobs a family
/// cannot express are pinned, so the lattice never contains two names
/// for the same simulation) with the shipped baseline configuration
/// always injected as the ranking's reference point.
#[derive(Clone, Debug)]
pub struct TuningLattice {
    /// Kernel families to tune.
    pub kernels: Vec<TuneKernel>,
    /// Scenario presets to rank under (one ranking group each).
    pub scenarios: Vec<ScenarioSpec>,
    /// Cache protocol for every cell.
    pub cache: CacheState,
    /// Data layouts to try.
    pub layouts: Vec<DataLayout>,
    /// Blocking factors to try (conv output-row block / inner-product
    /// M-tile; `0` = a pool kernel's unchunked baseline).
    pub blocks: Vec<usize>,
    /// Loop orders to try.
    pub orders: Vec<LoopOrder>,
    /// Software-prefetch distances (cache lines; `0` = the kernel's
    /// shipped prefetch behaviour).
    pub prefetch: Vec<usize>,
}

impl TuningLattice {
    /// The default search space: both hot kernel families, the paper's
    /// two main resource scenarios, both shipped layouts, three
    /// blocking factors, both loop orders and two prefetch distances —
    /// 30 canonical variants, 60 cold cells.
    pub fn default_lattice() -> TuningLattice {
        TuningLattice {
            kernels: vec![TuneKernel::ConvDirect, TuneKernel::InnerProduct],
            scenarios: vec![ScenarioSpec::single_thread(), ScenarioSpec::one_socket()],
            cache: CacheState::Cold,
            layouts: vec![DataLayout::Nchw, DataLayout::Nchw16c],
            blocks: vec![4, 8, 16],
            orders: vec![LoopOrder::IcInner, LoopOrder::IcOuter],
            prefetch: vec![0, 8],
        }
    }

    /// Expand the axes into canonical, deduplicated variant specs, in
    /// deterministic order: per family, the shipped baselines first
    /// (one per layout — rankings always contain their reference
    /// point), then the knob cross product. Canonicalisation collapses
    /// inexpressible knob combinations, so e.g. the inner product
    /// contributes one variant per (block, prefetch) pair regardless of
    /// how many layouts the lattice lists.
    pub fn variants(&self) -> Vec<VariantSpec> {
        let mut out: Vec<VariantSpec> = Vec::new();
        for &kernel in &self.kernels {
            for &layout in &self.layouts {
                let v = VariantSpec::canonical(kernel, kernel.baseline(layout));
                if !out.contains(&v) {
                    out.push(v);
                }
            }
            for &layout in &self.layouts {
                for &block in &self.blocks {
                    for &order in &self.orders {
                        for &prefetch_lines in &self.prefetch {
                            let v = VariantSpec::canonical(
                                kernel,
                                VariantParams { layout, block, order, prefetch_lines },
                            );
                            if !out.contains(&v) {
                                out.push(v);
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// The lattice as a synthetic grid experiment for the plan
    /// executor: one [`KernelSpec::Variant`] per canonical variant,
    /// every scenario, one cache state. The spec never enters the
    /// registry — [`plan::execute_specs_with_budget`] accepts it
    /// directly — but its cells hash and memoize exactly like registry
    /// cells, which is what makes warm re-tunes free.
    pub fn to_spec(&self) -> ExperimentSpec {
        ExperimentSpec {
            id: "tune",
            title: "roofline-guided variant tuning",
            kind: SpecKind::Grid(GridSpec {
                scenarios: self.scenarios.clone(),
                kernels: self
                    .variants()
                    .into_iter()
                    .map(KernelSpec::Variant)
                    .collect(),
                cache_states: vec![self.cache],
                expectations: vec![],
                notes: vec![],
                post: None,
            }),
        }
    }
}

/// One variant's measured position against its scenario's roofline.
#[derive(Clone, Debug)]
pub struct RankedVariant {
    /// Knob-tagged kernel display name (e.g. `conv_direct_nchw@rb4+pf8`).
    pub name: String,
    /// The variant's canonical knob values.
    pub spec: VariantSpec,
    /// Cell content hash (joins the ranking to `--explain` and the
    /// manifest).
    pub key: u64,
    /// Whether this is the family's shipped baseline configuration.
    pub baseline: bool,
    /// Work W (FLOPs).
    pub work_flops: f64,
    /// DRAM-level arithmetic intensity W/Q.
    pub ai: f64,
    /// Attainable FLOP/s under the hierarchical roofline at this
    /// variant's measured per-level traffic — the ranking key.
    pub attainable: f64,
    /// The roof that binds the variant (the winner's explanation).
    pub binding: Binding,
    /// Measured FLOP/s (W/R) — the first tie-break.
    pub perf: f64,
    /// Measured fraction of peak π.
    pub utilization: f64,
}

/// One kernel family's ranked variants under one scenario.
#[derive(Clone, Debug)]
pub struct KernelRanking {
    /// The family being ranked.
    pub kernel: TuneKernel,
    /// Variants, best first (see [`rank_order`]). Never empty.
    pub variants: Vec<RankedVariant>,
}

impl KernelRanking {
    /// The best-ranked variant.
    pub fn winner(&self) -> &RankedVariant {
        &self.variants[0]
    }

    /// The best-ranked shipped baseline, the winner's reference point.
    pub fn baseline(&self) -> Option<&RankedVariant> {
        self.variants.iter().find(|v| v.baseline)
    }
}

/// Rankings for every tuned family under one scenario.
#[derive(Clone, Debug)]
pub struct ScenarioRanking {
    /// Scenario preset name.
    pub scenario: String,
    /// One ranking per kernel family, in lattice order.
    pub rankings: Vec<KernelRanking>,
}

/// Everything one tuning run produced.
pub struct TuneReport {
    /// The lattice that was searched.
    pub lattice: TuningLattice,
    /// Canonical variants in the lattice.
    pub variant_count: usize,
    /// Per-scenario rankings, in lattice scenario order (inexpressible
    /// scenarios are skipped, like everywhere else in the executor).
    pub scenarios: Vec<ScenarioRanking>,
    /// Plan-shape statistics of the underlying execution.
    pub stats: PlanStats,
    /// Persistent-store accounting, when `--cache-dir` was active.
    pub store: Option<StoreUsage>,
    /// Every executed cell, in plan order (feeds the run manifest).
    pub cells: Vec<ExecutedCell>,
}

/// Total, deterministic ranking order: attainable FLOP/s descending,
/// then measured FLOP/s descending, then name ascending. All inputs are
/// finite (attainable is capped by π), so `partial_cmp` cannot
/// misorder; the name tie-break makes warm re-tunes and every `--jobs`
/// budget reproduce the ranking byte-for-byte.
pub fn rank_order(a: &RankedVariant, b: &RankedVariant) -> Ordering {
    b.attainable
        .partial_cmp(&a.attainable)
        .unwrap_or(Ordering::Equal)
        .then(b.perf.partial_cmp(&a.perf).unwrap_or(Ordering::Equal))
        .then_with(|| a.name.cmp(&b.name))
}

/// Execute a tuning lattice through the memoizing plan executor and
/// rank every variant. With a persistent `store`, unchanged variants
/// are served from disk — a warm re-tune of an unchanged lattice
/// simulates nothing.
pub fn run(
    lattice: &TuningLattice,
    params: &ExperimentParams,
    budget: JobBudget,
    store: Option<&CellStore>,
) -> Result<TuneReport> {
    let variants = lattice.variants();
    ensure!(!variants.is_empty(), "tuning lattice expands to no variants");
    ensure!(!lattice.scenarios.is_empty(), "tuning lattice names no scenarios");
    // Display name → canonical variant, via the same kernel construction
    // the executor uses (names are unique: distinct canonical variants
    // differ in at least one tagged knob).
    let by_name: Vec<(String, VariantSpec)> = variants
        .iter()
        .map(|v| (KernelSpec::Variant(*v).build(params).name(), *v))
        .collect();

    let outcome = plan::execute_specs_with_budget(vec![lattice.to_spec()], params, budget, false, store)?;

    let mut scenarios = Vec::new();
    for scenario in &lattice.scenarios {
        if scenario.validate(&params.machine).is_err() {
            continue;
        }
        let roofline = roofline_for(params, scenario);
        let mut rankings: Vec<KernelRanking> = lattice
            .kernels
            .iter()
            .map(|&kernel| KernelRanking { kernel, variants: Vec::new() })
            .collect();
        for cell in outcome
            .cells
            .iter()
            .filter(|c| !c.plan.reused && c.plan.scenario == scenario.name)
        {
            let spec = by_name
                .iter()
                .find(|(n, _)| *n == cell.plan.kernel)
                .map(|(_, v)| *v)
                .ok_or_else(|| {
                    anyhow!("cell kernel '{}' is not in the lattice (planner bug)", cell.plan.kernel)
                })?;
            let point = cell.measurement.point();
            let levels = cell.measurement.level_bytes();
            let (attainable, binding) = roofline.attainable_hier(point.work_flops, &levels);
            let ranked = RankedVariant {
                name: cell.plan.kernel.clone(),
                spec,
                key: cell.plan.key,
                baseline: spec.is_baseline(),
                work_flops: point.work_flops,
                ai: point.ai(),
                attainable,
                binding,
                perf: point.perf(),
                utilization: point.utilization(&roofline),
            };
            let slot = rankings
                .iter_mut()
                .find(|r| r.kernel == spec.base)
                .ok_or_else(|| anyhow!("variant family not in lattice (planner bug)"))?;
            slot.variants.push(ranked);
        }
        for r in &mut rankings {
            r.variants.sort_by(rank_order);
        }
        rankings.retain(|r| !r.variants.is_empty());
        scenarios.push(ScenarioRanking { scenario: scenario.name.clone(), rankings });
    }

    Ok(TuneReport {
        lattice: lattice.clone(),
        variant_count: variants.len(),
        scenarios,
        stats: outcome.stats,
        store: outcome.store,
        cells: outcome.cells,
    })
}

/// Paths one tuning run wrote.
#[derive(Clone, Debug)]
pub struct TuneOutput {
    /// The ranked markdown report.
    pub markdown: PathBuf,
    /// The flat per-variant CSV.
    pub csv: PathBuf,
    /// The structured tuning manifest section (`tune.json`).
    pub json: PathBuf,
    /// The standard versioned run manifest (`tune.run.json`).
    pub manifest: PathBuf,
}

/// Write the tuning report set under `out_dir`: `tune.md`, `tune.csv`,
/// `tune.json` (the structured manifest section) and `tune.run.json`
/// (the standard versioned run manifest recording every cell and file
/// checksum). All four are deterministic functions of the measurements
/// — no wall clock — so a warm re-tune rewrites them byte-identically.
pub fn write_reports(
    report: &TuneReport,
    params: &ExperimentParams,
    out_dir: &Path,
) -> Result<TuneOutput> {
    let md = report::markdown(report);
    let csv = report::csv(report);
    write_atomic(&out_dir.join("tune.md"), &md)?;
    write_atomic(&out_dir.join("tune.csv"), &csv)?;
    let files = vec![
        FileRecord::from_content("tune.md", &md),
        FileRecord::from_content("tune.csv", &csv),
    ];
    let json_text = report::manifest_json(report, params, &files).to_string_pretty();
    write_atomic(&out_dir.join("tune.json"), &json_text)?;
    let mut manifest = RunManifest::new(params, &["tune"], &report.cells, &report.stats);
    manifest.add_file("tune.md", &md);
    manifest.add_file("tune.csv", &csv);
    manifest.add_file("tune.json", &json_text);
    manifest.write(&out_dir.join("tune.run.json"))?;
    Ok(TuneOutput {
        markdown: out_dir.join("tune.md"),
        csv: out_dir.join("tune.csv"),
        json: out_dir.join("tune.json"),
        manifest: out_dir.join("tune.run.json"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> ExperimentParams {
        ExperimentParams { batch: Some(1), ..Default::default() }
    }

    #[test]
    fn default_lattice_meets_search_floor() {
        let lattice = TuningLattice::default_lattice();
        let variants = lattice.variants();
        // Conv: 2 layouts × 3 blocks × 2 orders × 2 prefetch = 24;
        // inner product canonicalises to 3 blocks × 2 prefetch = 6.
        assert_eq!(variants.len(), 30);
        assert!(variants.iter().any(|v| v.base == TuneKernel::ConvDirect && v.is_baseline()));
        assert!(variants.iter().any(|v| v.base == TuneKernel::InnerProduct && v.is_baseline()));
        // Canonicalisation + dedup leaves no duplicates.
        for (i, a) in variants.iter().enumerate() {
            assert!(!variants[i + 1..].contains(a), "duplicate variant {a:?}");
        }
    }

    #[test]
    fn to_spec_builds_full_grid() {
        let lattice = TuningLattice::default_lattice();
        let spec = lattice.to_spec();
        assert_eq!(spec.id, "tune");
        // 30 variants × 2 scenarios × 1 cache state.
        assert_eq!(spec.cells().len(), 60);
    }

    #[test]
    fn tiny_lattice_ranks_deterministically() {
        let lattice = TuningLattice {
            kernels: vec![TuneKernel::ConvDirect],
            scenarios: vec![ScenarioSpec::single_thread()],
            cache: CacheState::Cold,
            layouts: vec![DataLayout::Nchw],
            blocks: vec![8],
            orders: vec![LoopOrder::IcInner, LoopOrder::IcOuter],
            prefetch: vec![0],
        };
        let params = quick();
        let report = run(&lattice, &params, JobBudget::cells(1), None).unwrap();
        assert_eq!(report.variant_count, 2);
        assert_eq!(report.scenarios.len(), 1);
        let ranking = &report.scenarios[0].rankings[0];
        assert_eq!(ranking.variants.len(), 2);
        // Sorted best-first by attainable FLOP/s.
        assert!(ranking.variants[0].attainable >= ranking.variants[1].attainable);
        assert!(ranking.baseline().is_some());
        // Every variant carries a binding-level explanation.
        for v in &ranking.variants {
            assert!(!v.binding.label().is_empty());
        }
        // The ranking is reproducible bit-for-bit.
        let again = run(&lattice, &params, JobBudget::cells(1), None).unwrap();
        for (a, b) in report.scenarios[0].rankings[0]
            .variants
            .iter()
            .zip(again.scenarios[0].rankings[0].variants.iter())
        {
            assert_eq!(a.name, b.name);
            assert_eq!(a.attainable.to_bits(), b.attainable.to_bits());
        }
    }
}
