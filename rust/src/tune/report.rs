//! Tuning report renderers: ranked markdown tables, a flat per-variant
//! CSV, and the structured `tune.json` manifest section. Every renderer
//! is a pure function of the [`TuneReport`] — no wall clock, no
//! environment — so warm re-tunes reproduce all three byte-for-byte.

use crate::coordinator::manifest::FileRecord;
use crate::harness::experiments::ExperimentParams;
use crate::util::hash::hex64;
use crate::util::human::fmt_flops;
use crate::util::json::Json;

use super::{KernelRanking, RankedVariant, TuneReport};

fn axis_list<T, F: Fn(&T) -> String>(items: &[T], f: F) -> String {
    items.iter().map(f).collect::<Vec<_>>().join(", ")
}

/// One line explaining a family's winner through its binding roof,
/// against the best-ranked shipped baseline.
pub fn winner_line(ranking: &KernelRanking) -> String {
    let w = ranking.winner();
    let mut line = format!(
        "winner: `{}` — {}-bound, attainable {}, measured {}",
        w.name,
        w.binding.label(),
        fmt_flops(w.attainable),
        fmt_flops(w.perf),
    );
    match ranking.baseline() {
        Some(b) if b.name == w.name => {
            line.push_str(" (the shipped baseline already wins this lattice)");
        }
        Some(b) => {
            let ratio = if b.attainable > 0.0 { w.attainable / b.attainable } else { f64::INFINITY };
            if b.binding == w.binding {
                line.push_str(&format!(
                    " (baseline `{}` binds at the same {} roof; attainable ×{ratio:.2})",
                    b.name,
                    b.binding.label(),
                ));
            } else {
                line.push_str(&format!(
                    " (baseline `{}` is {}-bound — the winner moved the binding roof from {} to {}; attainable ×{ratio:.2})",
                    b.name,
                    b.binding.label(),
                    b.binding.label(),
                    w.binding.label(),
                ));
            }
        }
        None => line.push_str(" (no shipped baseline in this lattice)"),
    }
    line
}

/// The ranked markdown report (`tune.md`).
pub fn markdown(report: &TuneReport) -> String {
    let mut out = String::from("# roofline-guided tuning report\n\n");
    let l = &report.lattice;
    out.push_str(&format!(
        "lattice: {} canonical variants of [{}] under [{}] ({} cache)\n\n",
        report.variant_count,
        axis_list(&l.kernels, |k| k.label().to_string()),
        axis_list(&l.scenarios, |s| s.name.clone()),
        l.cache.label(),
    ));
    out.push_str(&format!(
        "axes: layouts [{}] × blocks [{}] × orders [{}] × prefetch [{}]\n\n",
        axis_list(&l.layouts, |d| d.label().to_string()),
        axis_list(&l.blocks, |b| b.to_string()),
        axis_list(&l.orders, |o| o.label().to_string()),
        axis_list(&l.prefetch, |p| p.to_string()),
    ));
    for scenario in &report.scenarios {
        out.push_str(&format!("## scenario {}\n\n", scenario.scenario));
        for ranking in &scenario.rankings {
            out.push_str(&format!("### {}\n\n", ranking.kernel.label()));
            out.push_str(
                "| rank | variant | layout | block | order | pf | AI | attainable | measured P | util π | bound |\n",
            );
            out.push_str("|---|---|---|---|---|---|---|---|---|---|---|\n");
            for (i, v) in ranking.variants.iter().enumerate() {
                out.push_str(&format!(
                    "| {} | {} | {} | {} | {} | {} | {:.3} | {} | {} | {:.1}% | {} |\n",
                    i + 1,
                    v.name,
                    v.spec.params.layout.label(),
                    v.spec.params.block,
                    v.spec.params.order.label(),
                    v.spec.params.prefetch_lines,
                    v.ai,
                    fmt_flops(v.attainable),
                    fmt_flops(v.perf),
                    v.utilization * 100.0,
                    v.binding.label(),
                ));
            }
            out.push_str(&format!("\n{}\n\n", winner_line(ranking)));
        }
    }
    out
}

fn csv_row(scenario: &str, kernel: &str, v: &RankedVariant) -> String {
    format!(
        "{scenario},{kernel},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
        v.name,
        v.spec.params.layout.label(),
        v.spec.params.block,
        v.spec.params.order.label(),
        v.spec.params.prefetch_lines,
        v.work_flops,
        v.ai,
        v.attainable,
        v.perf,
        v.utilization,
        v.binding.label(),
        v.baseline,
        hex64(v.key),
    )
}

/// The flat per-variant CSV (`tune.csv`), rows in ranking order.
/// Variant name tags use `@`/`+` separators precisely so they can never
/// introduce a column.
pub fn csv(report: &TuneReport) -> String {
    let mut out = String::from(
        "scenario,kernel,variant,layout,block,order,prefetch_lines,work_flops,ai,attainable_flops,perf_flops,util,bound,baseline,cell_key\n",
    );
    for scenario in &report.scenarios {
        for ranking in &scenario.rankings {
            for v in &ranking.variants {
                out.push_str(&csv_row(&scenario.scenario, ranking.kernel.label(), v));
            }
        }
    }
    out
}

fn variant_json(v: &RankedVariant) -> Json {
    Json::obj(vec![
        ("variant", Json::str(v.name.as_str())),
        ("layout", Json::str(v.spec.params.layout.label())),
        ("block", Json::num(v.spec.params.block as f64)),
        ("order", Json::str(v.spec.params.order.label())),
        ("prefetch_lines", Json::num(v.spec.params.prefetch_lines as f64)),
        ("work_flops", Json::num(v.work_flops)),
        ("ai", Json::num(v.ai)),
        ("attainable_flops", Json::num(v.attainable)),
        ("perf_flops", Json::num(v.perf)),
        ("util", Json::num(v.utilization)),
        ("bound", Json::str(v.binding.label())),
        ("baseline", Json::Bool(v.baseline)),
        ("cell_key", Json::str(hex64(v.key))),
    ])
}

/// The structured tuning manifest section (`tune.json`): the lattice
/// axes, every ranking, plan statistics and the checksums of the sibling
/// report files.
pub fn manifest_json(report: &TuneReport, params: &ExperimentParams, files: &[FileRecord]) -> Json {
    let l = &report.lattice;
    let lattice = Json::obj(vec![
        ("kernels", Json::arr(l.kernels.iter().map(|k| Json::str(k.label())).collect())),
        ("scenarios", Json::arr(l.scenarios.iter().map(|s| Json::str(s.name.as_str())).collect())),
        ("cache", Json::str(l.cache.label())),
        ("layouts", Json::arr(l.layouts.iter().map(|d| Json::str(d.label())).collect())),
        ("blocks", Json::arr(l.blocks.iter().map(|&b| Json::num(b as f64)).collect())),
        ("orders", Json::arr(l.orders.iter().map(|o| Json::str(o.label())).collect())),
        ("prefetch", Json::arr(l.prefetch.iter().map(|&p| Json::num(p as f64)).collect())),
        ("variant_count", Json::num(report.variant_count as f64)),
    ]);
    let scenarios = Json::arr(
        report
            .scenarios
            .iter()
            .map(|sc| {
                Json::obj(vec![
                    ("scenario", Json::str(sc.scenario.as_str())),
                    (
                        "rankings",
                        Json::arr(
                            sc.rankings
                                .iter()
                                .map(|r| {
                                    Json::obj(vec![
                                        ("kernel", Json::str(r.kernel.label())),
                                        ("winner", Json::str(r.winner().name.as_str())),
                                        (
                                            "variants",
                                            Json::arr(r.variants.iter().map(variant_json).collect()),
                                        ),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    let stats = Json::obj(vec![
        ("cells_total", Json::num(report.stats.cells_total as f64)),
        ("cells_simulated", Json::num(report.stats.cells_simulated as f64)),
        ("cells_reused", Json::num(report.stats.cells_reused as f64)),
        ("cells_skipped", Json::num(report.stats.cells_skipped as f64)),
    ]);
    Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("generator", Json::str(format!("dlroofline {}", crate::VERSION))),
        ("machine", params.machine.fingerprint_json()),
        ("machine_fingerprint", Json::str(params.machine.fingerprint())),
        ("lattice", lattice),
        ("scenarios", scenarios),
        ("stats", stats),
        (
            "files",
            Json::arr(
                files
                    .iter()
                    .map(|f| {
                        Json::obj(vec![
                            ("path", Json::str(f.path.as_str())),
                            ("bytes", Json::num(f.bytes as f64)),
                            ("checksum", Json::str(f.checksum.as_str())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan::JobBudget;
    use crate::harness::{CacheState, ScenarioSpec};
    use crate::kernels::{DataLayout, LoopOrder, TuneKernel};
    use crate::tune::TuningLattice;

    fn tiny_report() -> TuneReport {
        let lattice = TuningLattice {
            kernels: vec![TuneKernel::InnerProduct],
            scenarios: vec![ScenarioSpec::single_thread()],
            cache: CacheState::Cold,
            layouts: vec![DataLayout::Nchw],
            blocks: vec![16, 32],
            orders: vec![LoopOrder::IcInner],
            prefetch: vec![0],
        };
        let params = ExperimentParams { batch: Some(1), ..Default::default() };
        crate::tune::run(&lattice, &params, JobBudget::cells(1), None).unwrap()
    }

    #[test]
    fn markdown_ranks_and_explains() {
        let report = tiny_report();
        let md = markdown(&report);
        assert!(md.contains("## scenario single-thread"), "{md}");
        assert!(md.contains("### inner_product"), "{md}");
        assert!(md.contains("winner: `inner_product"), "{md}");
        assert!(md.contains("-bound"), "{md}");
        assert!(md.contains("inner_product@mt32"), "{md}");
    }

    #[test]
    fn csv_has_one_row_per_variant_and_no_stray_commas() {
        let report = tiny_report();
        let body = csv(&report);
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 1 + 2, "{body}");
        let columns = lines[0].split(',').count();
        for line in &lines {
            assert_eq!(line.split(',').count(), columns, "{line}");
        }
        assert!(body.contains(",bound,") || lines[0].ends_with("cell_key"));
    }

    #[test]
    fn manifest_json_is_structured_and_versioned() {
        let report = tiny_report();
        let params = ExperimentParams { batch: Some(1), ..Default::default() };
        let files = vec![FileRecord::from_content("tune.md", "x")];
        let doc = manifest_json(&report, &params, &files);
        assert_eq!(doc.expect("schema_version").unwrap().as_f64().unwrap(), 1.0);
        let text = doc.to_string_compact();
        assert!(text.contains("\"winner\""), "{text}");
        assert!(text.contains("\"tune.md\""), "{text}");
        // Round-trips through the parser.
        assert!(Json::parse(&text).is_ok());
    }
}
