//! Kernel registry: name → model factory, so the CLI (and downstream
//! users embedding the library) can measure any primitive ad hoc and
//! register their own.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::kernels::conv_direct::{ConvDirectBlocked, ConvDirectNchw};
use crate::kernels::conv_winograd::ConvWinograd;
use crate::kernels::gelu::{EltwiseShape, GeluBlocked, GeluNchw};
use crate::kernels::inner_product::InnerProduct;
use crate::kernels::layernorm::LayerNorm;
use crate::kernels::pooling::{AvgPoolBlocked, AvgPoolNchw, PoolShape};
use crate::kernels::reduction::SumReduction;
use crate::kernels::{ConvShape, KernelModel};

type Factory = Box<dyn Fn(usize) -> Box<dyn KernelModel> + Send + Sync>;

/// A registry of kernel factories keyed by name; the `usize` parameter is
/// the batch/problem scale.
pub struct KernelRegistry {
    factories: BTreeMap<String, Factory>,
}

impl Default for KernelRegistry {
    fn default() -> Self {
        Self::with_builtins()
    }
}

impl KernelRegistry {
    /// Registry with no kernels (for tests and custom setups).
    pub fn empty() -> KernelRegistry {
        KernelRegistry { factories: BTreeMap::new() }
    }

    /// All paper kernels pre-registered.
    pub fn with_builtins() -> KernelRegistry {
        let mut r = KernelRegistry::empty();
        r.register("conv_direct_nchw", |n| {
            Box::new(ConvDirectNchw::new(ConvShape::paper_conv(n)))
        });
        r.register("conv_direct_nchw16c", |n| {
            Box::new(ConvDirectBlocked::new(ConvShape::paper_conv(n)))
        });
        r.register("conv_winograd", |n| {
            Box::new(ConvWinograd::new(ConvShape::paper_conv(n)))
        });
        r.register("inner_product", |_| Box::new(InnerProduct::paper_shape()));
        r.register("avgpool_nchw", |n| Box::new(AvgPoolNchw::new(PoolShape::paper_pool(n))));
        r.register("avgpool_nchw16c", |n| {
            Box::new(AvgPoolBlocked::new(PoolShape::paper_pool(n)))
        });
        r.register("gelu_nchw", |n| Box::new(GeluNchw::new(EltwiseShape::paper_gelu(n))));
        r.register("gelu_nchw16c", |n| {
            Box::new(GeluBlocked::forced(EltwiseShape::paper_gelu(n)))
        });
        r.register("layernorm", |n| Box::new(LayerNorm::new(n.max(1) * 1024, 768)));
        r.register("sum_reduction", |n| {
            Box::new(SumReduction::new((n.max(1)) << 20))
        });
        r
    }

    /// Register (or replace) a factory.
    pub fn register(
        &mut self,
        name: &str,
        factory: impl Fn(usize) -> Box<dyn KernelModel> + Send + Sync + 'static,
    ) {
        self.factories.insert(name.to_string(), Box::new(factory));
    }

    /// Instantiate a kernel at the given scale.
    pub fn create(&self, name: &str, scale: usize) -> Result<Box<dyn KernelModel>> {
        let f = self.factories.get(name).ok_or_else(|| {
            anyhow!("unknown kernel '{name}' (have: {})", self.names().join(", "))
        })?;
        Ok(f(scale))
    }

    /// Registered kernel names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.factories.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_cover_paper_kernels() {
        let r = KernelRegistry::with_builtins();
        for name in [
            "conv_direct_nchw",
            "conv_direct_nchw16c",
            "conv_winograd",
            "inner_product",
            "avgpool_nchw",
            "avgpool_nchw16c",
            "gelu_nchw",
            "gelu_nchw16c",
            "layernorm",
            "sum_reduction",
        ] {
            let k = r.create(name, 1).unwrap();
            assert_eq!(k.name(), name, "factory name mismatch");
            assert!(k.flops() > 0.0, "{name} has zero flops");
        }
    }

    #[test]
    fn unknown_kernel_lists_options() {
        let r = KernelRegistry::with_builtins();
        let err = match r.create("bogus", 1) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("bogus kernel should not resolve"),
        };
        assert!(err.contains("inner_product"), "{err}");
    }

    #[test]
    fn user_registration_overrides() {
        let mut r = KernelRegistry::with_builtins();
        r.register("sum_reduction", |_| Box::new(SumReduction::new(1 << 10)));
        let k = r.create("sum_reduction", 99).unwrap();
        assert_eq!(k.flops(), 1024.0);
    }
}
