//! Manifest diffing: per-cell W/Q/R and per-level-AI drift between two
//! `run.json` manifests (ROADMAP: compare machines or code versions).
//!
//! Cells are matched by identity — (experiment, kernel, scenario,
//! cache) — not by content hash, so runs from different machines or
//! different code versions line up. Drift is relative:
//! `|a − b| / max(|a|, |b|)`, 0 when both sides are 0.
//!
//! ```
//! use dlroofline::coordinator::{diff_manifests, RunManifest};
//! use dlroofline::util::json::Json;
//!
//! let doc = r#"{
//!   "schema_version": 1, "generator": "dlroofline 0.1.0",
//!   "machine": {}, "machine_fingerprint": "00", "full_size": false,
//!   "batch": null, "experiments": ["f6"], "specials": 0,
//!   "cells_skipped": 0,
//!   "cells": [{ "experiment": "f6", "kernel": "inner_product",
//!     "scenario": "single-thread", "cache": "cold", "key": "ab",
//!     "reused": false, "threads": 1, "work_flops": 100,
//!     "traffic_bytes": 50, "runtime_seconds": 0.5 }],
//!   "files": []
//! }"#;
//! let a = RunManifest::from_json(&Json::parse(doc).unwrap()).unwrap();
//! let mut b = a.clone();
//! b.cells[0].runtime_seconds *= 1.10; // a 10% runtime regression
//! let report = diff_manifests(&a, &b);
//! assert!(report.exceeds(0.05), "10% R drift trips a 5% gate");
//! assert!(!report.exceeds(0.15), "…but not a 15% gate");
//! ```

use std::collections::BTreeMap;

use crate::util::human::fmt_pct;

use super::manifest::{CellRecord, RunManifest};

/// One metric's values on both sides and the relative drift.
#[derive(Clone, Debug)]
pub struct MetricDrift {
    /// Metric name (`W`, `Q`, `R`, or a per-level AI).
    pub metric: &'static str,
    /// Value in the first manifest.
    pub a: f64,
    /// Value in the second manifest.
    pub b: f64,
    /// Relative drift `|a - b| / max(|a|, |b|)` (0 when both are 0).
    pub rel: f64,
}

/// Drift of one matched cell.
#[derive(Clone, Debug)]
pub struct CellDrift {
    /// `experiment/kernel/scenario/cache`.
    pub identity: String,
    /// Every compared metric (W, Q, R, per-level AI), drifting or not.
    pub metrics: Vec<MetricDrift>,
}

impl CellDrift {
    /// The cell's worst relative drift.
    pub fn max_rel(&self) -> f64 {
        self.metrics.iter().fold(0.0, |m, d| m.max(d.rel))
    }
}

/// The complete comparison of two manifests.
#[derive(Clone, Debug, Default)]
pub struct DiffReport {
    /// Cell identities present only in the first manifest.
    pub only_in_a: Vec<String>,
    /// Cell identities present only in the second manifest.
    pub only_in_b: Vec<String>,
    /// Matched cells with their metric drifts, in identity order.
    pub cells: Vec<CellDrift>,
    /// Matched cells whose per-level AI could NOT be compared because at
    /// least one side carries no level breakdown (pre-v2 manifest).
    pub cells_without_levels: usize,
    /// Whether the machine fingerprints differ.
    pub machine_changed: bool,
}

impl DiffReport {
    /// Worst relative drift across all matched cells.
    pub fn max_rel(&self) -> f64 {
        self.cells.iter().fold(0.0, |m, c| m.max(c.max_rel()))
    }

    /// True when the comparison should fail a `--tol` gate: any metric
    /// drifts beyond `tol`, or the cell sets diverge structurally.
    pub fn exceeds(&self, tol: f64) -> bool {
        !self.only_in_a.is_empty() || !self.only_in_b.is_empty() || self.max_rel() > tol
    }
}

fn identity(c: &CellRecord) -> String {
    format!("{}/{}/{}/{}", c.experiment, c.kernel, c.scenario, c.cache)
}

fn rel_drift(a: f64, b: f64) -> f64 {
    let scale = a.abs().max(b.abs());
    if scale == 0.0 {
        0.0
    } else {
        (a - b).abs() / scale
    }
}

/// Below this many bytes a level is "quiet": AI = W/bytes is
/// ill-conditioned as bytes → 0, so a single stray cache line would
/// register as ~100% drift. Levels quiet on BOTH sides are not compared;
/// a quiet→substantial transition still registers as ~full drift — the
/// quiet side reports either a huge AI (few bytes) or the 0.0 sentinel
/// (exactly zero bytes), and both land far from the substantial side.
const QUIET_LEVEL_BYTES: f64 = 16.0 * 64.0;

/// Compare two manifests cell by cell.
pub fn diff_manifests(a: &RunManifest, b: &RunManifest) -> DiffReport {
    let index = |m: &RunManifest| -> BTreeMap<String, &CellRecord> {
        m.cells.iter().map(|c| (identity(c), c)).collect()
    };
    let ia = index(a);
    let ib = index(b);

    let mut report = DiffReport {
        machine_changed: a.machine_fingerprint != b.machine_fingerprint,
        ..Default::default()
    };
    for key in ia.keys() {
        if !ib.contains_key(key) {
            report.only_in_a.push(key.clone());
        }
    }
    for key in ib.keys() {
        if !ia.contains_key(key) {
            report.only_in_b.push(key.clone());
        }
    }
    for (key, ca) in &ia {
        let Some(cb) = ib.get(key) else { continue };
        let mut metrics = vec![
            ("work_flops", ca.work_flops as f64, cb.work_flops as f64),
            ("traffic_bytes", ca.traffic_bytes as f64, cb.traffic_bytes as f64),
            ("runtime_seconds", ca.runtime_seconds, cb.runtime_seconds),
        ];
        if let (Some(la), Some(lb)) = (&ca.levels, &cb.levels) {
            let (wa, wb) = (ca.work_flops as f64, cb.work_flops as f64);
            let ai = |w: f64, bytes: f64| if bytes > 0.0 { w / bytes } else { 0.0 };
            for (name, ba, bb) in [
                ("ai_l1", la.l1, lb.l1),
                ("ai_l2", la.l2, lb.l2),
                ("ai_llc", la.llc, lb.llc),
                ("ai_dram_local", la.dram_local, lb.dram_local),
                ("ai_dram_remote", la.dram_remote, lb.dram_remote),
            ] {
                if ba < QUIET_LEVEL_BYTES && bb < QUIET_LEVEL_BYTES {
                    continue;
                }
                metrics.push((name, ai(wa, ba), ai(wb, bb)));
            }
        } else {
            // One side predates schema v2: the per-level comparison never
            // ran for this cell — counted so the report can say so
            // instead of implying "no drift" covered it.
            report.cells_without_levels += 1;
        }
        report.cells.push(CellDrift {
            identity: key.clone(),
            metrics: metrics
                .into_iter()
                .map(|(metric, a, b)| MetricDrift { metric, a, b, rel: rel_drift(a, b) })
                .collect(),
        });
    }
    report
}

/// Render the report as markdown: one row per drifting metric, plus
/// structural divergences. Quiet cells are summarised, not listed.
pub fn render_diff(report: &DiffReport, tol: f64) -> String {
    let mut out = String::new();
    if report.machine_changed {
        out.push_str("> machine fingerprints differ\n\n");
    }
    if report.cells_without_levels > 0 {
        out.push_str(&format!(
            "> per-level AI not compared for {} cell(s): at least one manifest \
             predates schema v2 (no `levels`)\n\n",
            report.cells_without_levels
        ));
    }
    for (label, list) in [("only in A", &report.only_in_a), ("only in B", &report.only_in_b)] {
        for id in list {
            out.push_str(&format!("> {label}: {id}\n"));
        }
        if !list.is_empty() {
            out.push('\n');
        }
    }
    let drifting: Vec<(&CellDrift, Vec<&MetricDrift>)> = report
        .cells
        .iter()
        .filter_map(|c| {
            let bad: Vec<&MetricDrift> = c.metrics.iter().filter(|m| m.rel > tol).collect();
            if bad.is_empty() { None } else { Some((c, bad)) }
        })
        .collect();
    if drifting.is_empty() {
        if report.only_in_a.is_empty() && report.only_in_b.is_empty() {
            out.push_str(&format!(
                "no drift above tolerance ({} cells compared, worst {})\n",
                report.cells.len(),
                fmt_pct(report.max_rel()),
            ));
        } else {
            // Structural divergence only: don't print an empty metric
            // table that reads like a pass.
            out.push_str(&format!(
                "cell sets diverge ({} only in A, {} only in B); the {} matched \
                 cell(s) stay within tolerance\n",
                report.only_in_a.len(),
                report.only_in_b.len(),
                report.cells.len(),
            ));
        }
        return out;
    }
    out.push_str("| cell | metric | A | B | drift |\n|---|---|---|---|---|\n");
    for (cell, metrics) in &drifting {
        for m in metrics {
            out.push_str(&format!(
                "| {} | {} | {:.6e} | {:.6e} | {} |\n",
                cell.identity,
                m.metric,
                m.a,
                m.b,
                fmt_pct(m.rel)
            ));
        }
    }
    out.push_str(&format!(
        "\n{} of {} cells drift above {} (worst {})\n",
        drifting.len(),
        report.cells.len(),
        fmt_pct(tol),
        fmt_pct(report.max_rel()),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan;
    use crate::coordinator::manifest::RunManifest;
    use crate::harness::experiments::ExperimentParams;

    // f8's GELU kernels scale with the batch override, so two batches
    // produce genuinely different W/Q/R.
    fn manifest(batch: usize) -> RunManifest {
        let params = ExperimentParams { batch: Some(batch), ..Default::default() };
        let outcome = plan::execute(&["f8"], &params, 1, false).unwrap();
        RunManifest::new(&params, &["f8"], &outcome.cells, &outcome.stats)
    }

    #[test]
    fn identical_manifests_do_not_drift() {
        let a = manifest(1);
        let b = manifest(1);
        let report = diff_manifests(&a, &b);
        assert!(!report.exceeds(0.0), "max_rel = {}", report.max_rel());
        assert!(report.only_in_a.is_empty() && report.only_in_b.is_empty());
        assert!(!report.machine_changed);
        assert_eq!(report.cells.len(), 4); // f8: 2 kernels × cold + warm
        let text = render_diff(&report, 0.0);
        assert!(text.contains("no drift"), "{text}");
    }

    #[test]
    fn workload_change_registers_as_drift() {
        let a = manifest(1);
        let b = manifest(2); // double batch: W and Q both move
        let report = diff_manifests(&a, &b);
        assert!(report.exceeds(0.01));
        let text = render_diff(&report, 0.01);
        assert!(text.contains("work_flops"), "{text}");
        assert!(text.contains("drift"), "{text}");
    }

    #[test]
    fn missing_cells_are_structural_drift() {
        let a = manifest(1);
        let mut b = manifest(1);
        b.cells.pop();
        let report = diff_manifests(&a, &b);
        assert_eq!(report.only_in_a.len(), 1);
        assert!(report.exceeds(f64::INFINITY), "structural drift ignores tol");
        assert!(render_diff(&report, 0.0).contains("only in A"));
    }

    #[test]
    fn v1_manifest_comparison_reports_skipped_level_metrics() {
        let a = manifest(1);
        let mut b = manifest(1);
        for cell in &mut b.cells {
            cell.levels = None; // what loading a v1 manifest produces
        }
        let report = diff_manifests(&a, &b);
        assert_eq!(report.cells_without_levels, 4);
        // W/Q/R still compare clean…
        assert!(!report.exceeds(0.0));
        // …but the report says the per-level check never ran.
        let text = render_diff(&report, 0.0);
        assert!(text.contains("per-level AI not compared for 4 cell(s)"), "{text}");
    }

    #[test]
    fn rel_drift_is_symmetric_and_bounded() {
        assert_eq!(rel_drift(0.0, 0.0), 0.0);
        assert_eq!(rel_drift(1.0, 0.0), 1.0);
        assert_eq!(rel_drift(1.0, 2.0), rel_drift(2.0, 1.0));
        assert!((rel_drift(99.0, 100.0) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn quiet_levels_do_not_register_noise_drift() {
        // One stray cache line at an otherwise-silent level must not fail
        // the gate; a substantial change at that level must.
        let a = manifest(1);
        let mut noisy = manifest(1);
        let mut regressed = manifest(1);
        for cell in &mut noisy.cells {
            cell.levels.as_mut().unwrap().dram_remote = 64.0; // one line
        }
        for cell in &mut regressed.cells {
            cell.levels.as_mut().unwrap().dram_remote = 64.0 * 1024.0 * 1024.0;
        }
        let quiet = diff_manifests(&a, &noisy);
        assert!(
            !quiet.cells.iter().any(|c| c.metrics.iter().any(|m| m.metric == "ai_dram_remote")),
            "one stray line must stay below the quiet floor"
        );
        let loud = diff_manifests(&a, &regressed);
        assert!(
            loud.cells.iter().any(|c| c
                .metrics
                .iter()
                .any(|m| m.metric == "ai_dram_remote" && m.rel > 0.9)),
            "a 64 MiB remote-traffic regression must register"
        );
    }
}
