//! Manifest diffing: per-cell W/Q/R and per-level-AI drift between two
//! `run.json` manifests (ROADMAP: compare machines or code versions).
//! Also home of the bench-artifact comparison behind `dlroofline bench
//! diff` ([`diff_bench_docs`]) — the same gate idea applied to
//! `BENCH_<group>.json` timings, where only *slowdowns* trip the gate.
//!
//! Cells are matched by identity — (experiment, kernel, scenario,
//! cache) — not by content hash, so runs from different machines or
//! different code versions line up. Drift is relative:
//! `|a − b| / max(|a|, |b|)`, 0 when both sides are 0.
//!
//! ```
//! use dlroofline::coordinator::{diff_manifests, RunManifest};
//! use dlroofline::util::json::Json;
//!
//! let doc = r#"{
//!   "schema_version": 1, "generator": "dlroofline 0.1.0",
//!   "machine": {}, "machine_fingerprint": "00", "full_size": false,
//!   "batch": null, "experiments": ["f6"], "specials": 0,
//!   "cells_skipped": 0,
//!   "cells": [{ "experiment": "f6", "kernel": "inner_product",
//!     "scenario": "single-thread", "cache": "cold", "key": "ab",
//!     "reused": false, "threads": 1, "work_flops": 100,
//!     "traffic_bytes": 50, "runtime_seconds": 0.5 }],
//!   "files": []
//! }"#;
//! let a = RunManifest::from_json(&Json::parse(doc).unwrap()).unwrap();
//! let mut b = a.clone();
//! b.cells[0].runtime_seconds *= 1.10; // a 10% runtime regression
//! let report = diff_manifests(&a, &b);
//! assert!(report.exceeds(0.05), "10% R drift trips a 5% gate");
//! assert!(!report.exceeds(0.15), "…but not a 15% gate");
//! ```

use std::collections::BTreeMap;

use anyhow::{ensure, Context, Result};

use crate::util::human::fmt_pct;
use crate::util::json::Json;

use super::manifest::{CellRecord, RunManifest};

/// One metric's values on both sides and the relative drift.
#[derive(Clone, Debug)]
pub struct MetricDrift {
    /// Metric name (`W`, `Q`, `R`, or a per-level AI).
    pub metric: &'static str,
    /// Value in the first manifest.
    pub a: f64,
    /// Value in the second manifest.
    pub b: f64,
    /// Relative drift `|a - b| / max(|a|, |b|)` (0 when both are 0).
    pub rel: f64,
}

/// Drift of one matched cell.
#[derive(Clone, Debug)]
pub struct CellDrift {
    /// `experiment/kernel/scenario/cache`.
    pub identity: String,
    /// Every compared metric (W, Q, R, per-level AI), drifting or not.
    pub metrics: Vec<MetricDrift>,
}

impl CellDrift {
    /// The cell's worst relative drift.
    pub fn max_rel(&self) -> f64 {
        self.metrics.iter().fold(0.0, |m, d| m.max(d.rel))
    }
}

/// The complete comparison of two manifests.
#[derive(Clone, Debug, Default)]
pub struct DiffReport {
    /// Cell identities present only in the first manifest.
    pub only_in_a: Vec<String>,
    /// Cell identities present only in the second manifest.
    pub only_in_b: Vec<String>,
    /// Matched cells with their metric drifts, in identity order.
    pub cells: Vec<CellDrift>,
    /// Matched cells whose per-level AI could NOT be compared because at
    /// least one side carries no level breakdown (pre-v2 manifest).
    pub cells_without_levels: usize,
    /// Whether the machine fingerprints differ.
    pub machine_changed: bool,
}

impl DiffReport {
    /// Worst relative drift across all matched cells.
    pub fn max_rel(&self) -> f64 {
        self.cells.iter().fold(0.0, |m, c| m.max(c.max_rel()))
    }

    /// True when the comparison should fail a `--tol` gate: any metric
    /// drifts beyond `tol`, or the cell sets diverge structurally.
    pub fn exceeds(&self, tol: f64) -> bool {
        !self.only_in_a.is_empty() || !self.only_in_b.is_empty() || self.max_rel() > tol
    }
}

fn identity(c: &CellRecord) -> String {
    format!("{}/{}/{}/{}", c.experiment, c.kernel, c.scenario, c.cache)
}

/// Relative drift `|a − b| / max(|a|, |b|)`, hardened so it never
/// returns NaN: NaN would propagate through the division and then
/// silently vanish in [`CellDrift::max_rel`]'s `f64::max` (which keeps
/// the non-NaN operand), letting a corrupt manifest pass any gate. A
/// one-sided NaN or a finite-vs-infinite mismatch reads as maximal
/// drift; two identically non-finite sides read as no drift.
fn rel_drift(a: f64, b: f64) -> f64 {
    if a.is_nan() && b.is_nan() {
        return 0.0;
    }
    if a.is_nan() || b.is_nan() {
        return f64::INFINITY;
    }
    if !a.is_finite() || !b.is_finite() {
        return if a == b { 0.0 } else { f64::INFINITY };
    }
    let scale = a.abs().max(b.abs());
    if scale == 0.0 {
        0.0
    } else {
        (a - b).abs() / scale
    }
}

/// Below this many bytes a level is "quiet": AI = W/bytes is
/// ill-conditioned as bytes → 0, so a single stray cache line would
/// register as ~100% drift. Levels quiet on BOTH sides are not compared;
/// a quiet→substantial transition still registers as ~full drift — the
/// quiet side reports either a huge AI (few bytes) or the 0.0 sentinel
/// (exactly zero bytes), and both land far from the substantial side.
const QUIET_LEVEL_BYTES: f64 = 16.0 * 64.0;

/// Compare two manifests cell by cell.
pub fn diff_manifests(a: &RunManifest, b: &RunManifest) -> DiffReport {
    let index = |m: &RunManifest| -> BTreeMap<String, &CellRecord> {
        m.cells.iter().map(|c| (identity(c), c)).collect()
    };
    let ia = index(a);
    let ib = index(b);

    let mut report = DiffReport {
        machine_changed: a.machine_fingerprint != b.machine_fingerprint,
        ..Default::default()
    };
    for key in ia.keys() {
        if !ib.contains_key(key) {
            report.only_in_a.push(key.clone());
        }
    }
    for key in ib.keys() {
        if !ia.contains_key(key) {
            report.only_in_b.push(key.clone());
        }
    }
    for (key, ca) in &ia {
        let Some(cb) = ib.get(key) else { continue };
        let mut metrics = vec![
            ("work_flops", ca.work_flops as f64, cb.work_flops as f64),
            ("traffic_bytes", ca.traffic_bytes as f64, cb.traffic_bytes as f64),
            ("runtime_seconds", ca.runtime_seconds, cb.runtime_seconds),
        ];
        if let (Some(la), Some(lb)) = (&ca.levels, &cb.levels) {
            let (wa, wb) = (ca.work_flops as f64, cb.work_flops as f64);
            let ai = |w: f64, bytes: f64| if bytes > 0.0 { w / bytes } else { 0.0 };
            for (name, ba, bb) in [
                ("ai_l1", la.l1, lb.l1),
                ("ai_l2", la.l2, lb.l2),
                ("ai_llc", la.llc, lb.llc),
                ("ai_dram_local", la.dram_local, lb.dram_local),
                ("ai_dram_remote", la.dram_remote, lb.dram_remote),
            ] {
                if ba < QUIET_LEVEL_BYTES && bb < QUIET_LEVEL_BYTES {
                    continue;
                }
                metrics.push((name, ai(wa, ba), ai(wb, bb)));
            }
        } else {
            // One side predates schema v2: the per-level comparison never
            // ran for this cell — counted so the report can say so
            // instead of implying "no drift" covered it.
            report.cells_without_levels += 1;
        }
        report.cells.push(CellDrift {
            identity: key.clone(),
            metrics: metrics
                .into_iter()
                .map(|(metric, a, b)| MetricDrift { metric, a, b, rel: rel_drift(a, b) })
                .collect(),
        });
    }
    report
}

/// Render the report as markdown: one row per drifting metric, plus
/// structural divergences. Quiet cells are summarised, not listed.
pub fn render_diff(report: &DiffReport, tol: f64) -> String {
    let mut out = String::new();
    if report.machine_changed {
        out.push_str("> machine fingerprints differ\n\n");
    }
    if report.cells_without_levels > 0 {
        out.push_str(&format!(
            "> per-level AI not compared for {} cell(s): at least one manifest \
             predates schema v2 (no `levels`)\n\n",
            report.cells_without_levels
        ));
    }
    for (label, list) in [("only in A", &report.only_in_a), ("only in B", &report.only_in_b)] {
        for id in list {
            out.push_str(&format!("> {label}: {id}\n"));
        }
        if !list.is_empty() {
            out.push('\n');
        }
    }
    let drifting: Vec<(&CellDrift, Vec<&MetricDrift>)> = report
        .cells
        .iter()
        .filter_map(|c| {
            let bad: Vec<&MetricDrift> = c.metrics.iter().filter(|m| m.rel > tol).collect();
            if bad.is_empty() { None } else { Some((c, bad)) }
        })
        .collect();
    if drifting.is_empty() {
        if report.only_in_a.is_empty() && report.only_in_b.is_empty() {
            out.push_str(&format!(
                "no drift above tolerance ({} cells compared, worst {})\n",
                report.cells.len(),
                fmt_pct(report.max_rel()),
            ));
        } else {
            // Structural divergence only: don't print an empty metric
            // table that reads like a pass.
            out.push_str(&format!(
                "cell sets diverge ({} only in A, {} only in B); the {} matched \
                 cell(s) stay within tolerance\n",
                report.only_in_a.len(),
                report.only_in_b.len(),
                report.cells.len(),
            ));
        }
        return out;
    }
    out.push_str("| cell | metric | A | B | drift |\n|---|---|---|---|---|\n");
    for (cell, metrics) in &drifting {
        for m in metrics {
            out.push_str(&format!(
                "| {} | {} | {:.6e} | {:.6e} | {} |\n",
                cell.identity,
                m.metric,
                m.a,
                m.b,
                fmt_pct(m.rel)
            ));
        }
    }
    out.push_str(&format!(
        "\n{} of {} cells drift above {} (worst {})\n",
        drifting.len(),
        report.cells.len(),
        fmt_pct(tol),
        fmt_pct(report.max_rel()),
    ));
    out
}

/// One benchmark case compared between two `BENCH_<group>.json`
/// artifacts.
#[derive(Clone, Debug)]
pub struct BenchCaseDrift {
    /// Bench name within the group.
    pub name: String,
    /// Mean seconds on the A (baseline) side.
    pub a_mean: f64,
    /// Mean seconds on the B (candidate) side.
    pub b_mean: f64,
    /// Signed relative change `(b − a) / a`: positive = B is slower.
    pub change: f64,
    /// The tolerance applied to this case (per-case override, else the
    /// default).
    pub tol: f64,
}

impl BenchCaseDrift {
    /// True when B is slower than A by more than this case's tolerance.
    pub fn regressed(&self) -> bool {
        self.change > self.tol
    }
}

/// The comparison of two bench artifacts (`dlroofline bench diff`).
#[derive(Clone, Debug, Default)]
pub struct BenchDiffReport {
    /// The bench group both artifacts belong to (must match).
    pub group: String,
    /// Matched cases, in name order.
    pub cases: Vec<BenchCaseDrift>,
    /// Cases only the baseline has — a disappeared bench fails the gate.
    pub only_in_a: Vec<String>,
    /// Cases only the candidate has — informational, never gated.
    pub only_in_b: Vec<String>,
    /// At least one side ran in quick mode (`DLROOFLINE_BENCH_QUICK`):
    /// smoke-sized samples, so means are noisy.
    pub quick: bool,
    /// The host fingerprints differ — timings are not like-for-like.
    pub host_changed: bool,
}

impl BenchDiffReport {
    /// True when the gate should fail (exit 3): some case slowed beyond
    /// its tolerance, or a baseline case disappeared. Improvements and
    /// host/quick warnings never fail the gate.
    pub fn regressed(&self) -> bool {
        !self.only_in_a.is_empty() || self.cases.iter().any(|c| c.regressed())
    }

    /// The worst relative slowdown across matched cases, clamped at 0 —
    /// improvements never read as negative badness.
    pub fn worst_change(&self) -> f64 {
        self.cases.iter().fold(0.0_f64, |m, c| m.max(c.change))
    }
}

/// Compare two benchkit documents (`BENCH_<group>.json`, schema 1).
/// `default_tol` is the allowed relative slowdown (0.2 = B may be up to
/// 20% slower); `case_tols` overrides it per bench name and rejects
/// names that exist in neither document (a typo'd override must not
/// silently gate nothing).
pub fn diff_bench_docs(
    a: &Json,
    b: &Json,
    default_tol: f64,
    case_tols: &BTreeMap<String, f64>,
) -> Result<BenchDiffReport> {
    let check = |doc: &Json, side: &str| -> Result<()> {
        let version = doc.expect("schema_version")?.as_usize()?;
        ensure!(version == 1, "{side}: bench schema version {version} (this build reads 1)");
        Ok(())
    };
    check(a, "A")?;
    check(b, "B")?;
    let group_a = a.expect("group")?.as_str()?;
    let group_b = b.expect("group")?.as_str()?;
    ensure!(group_a == group_b, "bench groups differ: '{group_a}' vs '{group_b}'");
    let benches_a = a.expect("benches")?.as_obj()?;
    let benches_b = b.expect("benches")?.as_obj()?;
    for name in case_tols.keys() {
        ensure!(
            benches_a.contains_key(name) || benches_b.contains_key(name),
            "--case-tol names unknown bench '{name}'"
        );
    }
    let quick_of =
        |doc: &Json| doc.get("quick").map(|q| q.as_bool().unwrap_or(false)).unwrap_or(false);
    let mut report = BenchDiffReport {
        group: group_a.to_string(),
        quick: quick_of(a) || quick_of(b),
        host_changed: a.get("host") != b.get("host"),
        ..Default::default()
    };
    for name in benches_a.keys() {
        if !benches_b.contains_key(name) {
            report.only_in_a.push(name.clone());
        }
    }
    for name in benches_b.keys() {
        if !benches_a.contains_key(name) {
            report.only_in_b.push(name.clone());
        }
    }
    for (name, entry_a) in benches_a {
        let Some(entry_b) = benches_b.get(name) else { continue };
        let mean = |entry: &Json, side: &str| -> Result<f64> {
            entry
                .expect("mean_s")
                .and_then(|v| v.as_f64())
                .with_context(|| format!("{side}: bench '{name}'"))
        };
        let a_mean = mean(entry_a, "A")?;
        let b_mean = mean(entry_b, "B")?;
        // A NaN mean (hand-edited or foreign artifact) must fail the
        // gate, not fall through the comparisons below as "no change".
        let change = if a_mean.is_nan() || b_mean.is_nan() {
            f64::INFINITY
        } else if a_mean > 0.0 {
            (b_mean - a_mean) / a_mean
        } else if b_mean > 0.0 {
            f64::INFINITY
        } else {
            0.0
        };
        let tol = case_tols.get(name).copied().unwrap_or(default_tol);
        report.cases.push(BenchCaseDrift { name: name.clone(), a_mean, b_mean, change, tol });
    }
    Ok(report)
}

/// Render the comparison as markdown: warnings first, then every matched
/// case (slowest first) with its verdict, then the gate summary.
pub fn render_bench_diff(report: &BenchDiffReport) -> String {
    let mut out = String::new();
    out.push_str(&format!("## bench diff — {}\n\n", report.group));
    let mut warned = false;
    if report.quick {
        out.push_str("> at least one side ran in quick mode: smoke-sized samples, noisy means\n");
        warned = true;
    }
    if report.host_changed {
        out.push_str("> host fingerprints differ: timings are not like-for-like\n");
        warned = true;
    }
    for name in &report.only_in_b {
        out.push_str(&format!("> new in B (not gated): {name}\n"));
        warned = true;
    }
    for name in &report.only_in_a {
        out.push_str(&format!("> missing from B (fails the gate): {name}\n"));
        warned = true;
    }
    if warned {
        out.push('\n');
    }
    if !report.cases.is_empty() {
        let mut cases: Vec<&BenchCaseDrift> = report.cases.iter().collect();
        cases.sort_by(|x, y| y.change.total_cmp(&x.change));
        out.push_str("| bench | A mean | B mean | change | tol | verdict |\n");
        out.push_str("|---|---|---|---|---|---|\n");
        for c in cases {
            let verdict = if c.regressed() {
                "REGRESSED"
            } else if c.change < -c.tol {
                "improved"
            } else {
                "ok"
            };
            out.push_str(&format!(
                "| {} | {:.3e} s | {:.3e} s | {:+.1}% | {:.0}% | {} |\n",
                c.name,
                c.a_mean,
                c.b_mean,
                c.change * 100.0,
                c.tol * 100.0,
                verdict
            ));
        }
        out.push('\n');
    }
    if report.regressed() {
        out.push_str(&format!(
            "{} case(s) regressed beyond tolerance, {} missing from B\n",
            report.cases.iter().filter(|c| c.regressed()).count(),
            report.only_in_a.len(),
        ));
    } else {
        out.push_str(&format!(
            "no regressions ({} case(s) within tolerance, worst change {:+.1}%)\n",
            report.cases.len(),
            report.worst_change() * 100.0,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan;
    use crate::coordinator::manifest::RunManifest;
    use crate::harness::experiments::ExperimentParams;

    // f8's GELU kernels scale with the batch override, so two batches
    // produce genuinely different W/Q/R.
    fn manifest(batch: usize) -> RunManifest {
        let params = ExperimentParams { batch: Some(batch), ..Default::default() };
        let outcome = plan::execute(&["f8"], &params, 1, false).unwrap();
        RunManifest::new(&params, &["f8"], &outcome.cells, &outcome.stats)
    }

    #[test]
    fn identical_manifests_do_not_drift() {
        let a = manifest(1);
        let b = manifest(1);
        let report = diff_manifests(&a, &b);
        assert!(!report.exceeds(0.0), "max_rel = {}", report.max_rel());
        assert!(report.only_in_a.is_empty() && report.only_in_b.is_empty());
        assert!(!report.machine_changed);
        assert_eq!(report.cells.len(), 4); // f8: 2 kernels × cold + warm
        let text = render_diff(&report, 0.0);
        assert!(text.contains("no drift"), "{text}");
    }

    #[test]
    fn workload_change_registers_as_drift() {
        let a = manifest(1);
        let b = manifest(2); // double batch: W and Q both move
        let report = diff_manifests(&a, &b);
        assert!(report.exceeds(0.01));
        let text = render_diff(&report, 0.01);
        assert!(text.contains("work_flops"), "{text}");
        assert!(text.contains("drift"), "{text}");
    }

    #[test]
    fn missing_cells_are_structural_drift() {
        let a = manifest(1);
        let mut b = manifest(1);
        b.cells.pop();
        let report = diff_manifests(&a, &b);
        assert_eq!(report.only_in_a.len(), 1);
        assert!(report.exceeds(f64::INFINITY), "structural drift ignores tol");
        assert!(render_diff(&report, 0.0).contains("only in A"));
    }

    #[test]
    fn v1_manifest_comparison_reports_skipped_level_metrics() {
        let a = manifest(1);
        let mut b = manifest(1);
        for cell in &mut b.cells {
            cell.levels = None; // what loading a v1 manifest produces
        }
        let report = diff_manifests(&a, &b);
        assert_eq!(report.cells_without_levels, 4);
        // W/Q/R still compare clean…
        assert!(!report.exceeds(0.0));
        // …but the report says the per-level check never ran.
        let text = render_diff(&report, 0.0);
        assert!(text.contains("per-level AI not compared for 4 cell(s)"), "{text}");
    }

    #[test]
    fn rel_drift_is_symmetric_and_bounded() {
        assert_eq!(rel_drift(0.0, 0.0), 0.0);
        assert_eq!(rel_drift(1.0, 0.0), 1.0);
        assert_eq!(rel_drift(1.0, 2.0), rel_drift(2.0, 1.0));
        assert!((rel_drift(99.0, 100.0) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn rel_drift_never_returns_nan() {
        // Zero baseline and both-zero.
        assert_eq!(rel_drift(0.0, 5.0), 1.0);
        assert_eq!(rel_drift(5.0, 0.0), 1.0);
        assert_eq!(rel_drift(0.0, 0.0), 0.0);
        assert_eq!(rel_drift(0.0, -3.0), 1.0);
        // One-sided NaN reads as maximal drift (f64::max would have
        // silently dropped a NaN rel).
        assert_eq!(rel_drift(f64::NAN, 1.0), f64::INFINITY);
        assert_eq!(rel_drift(1.0, f64::NAN), f64::INFINITY);
        assert_eq!(rel_drift(f64::NAN, 0.0), f64::INFINITY);
        // Two identically broken sides carry no drift *between* them.
        assert_eq!(rel_drift(f64::NAN, f64::NAN), 0.0);
        assert_eq!(rel_drift(f64::INFINITY, f64::INFINITY), 0.0);
        // A finite-vs-infinite mismatch is maximal drift, not NaN.
        assert_eq!(rel_drift(f64::INFINITY, 1.0), f64::INFINITY);
        assert_eq!(rel_drift(1.0, f64::NEG_INFINITY), f64::INFINITY);
        assert_eq!(rel_drift(f64::INFINITY, f64::NEG_INFINITY), f64::INFINITY);
    }

    #[test]
    fn nan_runtime_fails_the_diff_gate() {
        let a = manifest(1);
        let mut b = manifest(1);
        b.cells[0].runtime_seconds = f64::NAN;
        let report = diff_manifests(&a, &b);
        assert_eq!(report.max_rel(), f64::INFINITY);
        assert!(report.exceeds(f64::MAX), "a NaN metric must gate at any tolerance");
    }

    #[test]
    fn bench_diff_nan_mean_fails_gate() {
        let a = bench_doc("grp", false, &[("x", 1.0)]);
        let mut b = bench_doc("grp", false, &[("x", 1.0)]);
        // Our writer never emits NaN (it serializes as null), but a
        // foreign or hand-edited artifact can carry one.
        if let Json::Obj(doc) = &mut b {
            if let Some(Json::Obj(benches)) = doc.get_mut("benches") {
                if let Some(Json::Obj(entry)) = benches.get_mut("x") {
                    entry.insert("mean_s".into(), Json::Num(f64::NAN));
                }
            }
        }
        let report = diff_bench_docs(&a, &b, 0.2, &BTreeMap::new()).unwrap();
        assert_eq!(report.cases[0].change, f64::INFINITY);
        assert!(report.regressed(), "a NaN mean must fail the gate");
        // Both sides NaN is still a gate failure: the metric is unusable.
        let report = diff_bench_docs(&b, &b, 0.2, &BTreeMap::new()).unwrap();
        assert!(report.regressed());
    }

    #[test]
    fn quiet_levels_do_not_register_noise_drift() {
        // One stray cache line at an otherwise-silent level must not fail
        // the gate; a substantial change at that level must.
        let a = manifest(1);
        let mut noisy = manifest(1);
        let mut regressed = manifest(1);
        for cell in &mut noisy.cells {
            cell.levels.as_mut().unwrap().dram_remote = 64.0; // one line
        }
        for cell in &mut regressed.cells {
            cell.levels.as_mut().unwrap().dram_remote = 64.0 * 1024.0 * 1024.0;
        }
        let quiet = diff_manifests(&a, &noisy);
        assert!(
            !quiet.cells.iter().any(|c| c.metrics.iter().any(|m| m.metric == "ai_dram_remote")),
            "one stray line must stay below the quiet floor"
        );
        let loud = diff_manifests(&a, &regressed);
        assert!(
            loud.cells.iter().any(|c| c
                .metrics
                .iter()
                .any(|m| m.metric == "ai_dram_remote" && m.rel > 0.9)),
            "a 64 MiB remote-traffic regression must register"
        );
    }

    fn bench_doc(group: &str, quick: bool, means: &[(&str, f64)]) -> Json {
        let benches: Vec<String> = means
            .iter()
            .map(|(name, mean)| format!("\"{name}\":{{\"mean_s\":{mean},\"samples\":3}}"))
            .collect();
        let text = format!(
            "{{\"schema_version\":1,\"group\":\"{group}\",\"quick\":{quick},\
             \"host\":{{\"os\":\"linux\"}},\"benches\":{{{}}}}}",
            benches.join(",")
        );
        Json::parse(&text).unwrap()
    }

    #[test]
    fn bench_diff_gates_slowdowns_only() {
        let a = bench_doc("grp", false, &[("fast", 1.0), ("slow", 2.0)]);
        let b = bench_doc("grp", false, &[("fast", 1.05), ("slow", 1.0)]);
        let report = diff_bench_docs(&a, &b, 0.10, &BTreeMap::new()).unwrap();
        assert!(!report.regressed(), "5% slower + 50% faster is within a 10% gate");
        assert!((report.worst_change() - 0.05).abs() < 1e-9);

        let tight = diff_bench_docs(&a, &b, 0.01, &BTreeMap::new()).unwrap();
        assert!(tight.regressed(), "5% slowdown must trip a 1% gate");
        let text = render_bench_diff(&tight);
        assert!(text.contains("REGRESSED"), "{text}");
        assert!(text.contains("improved"), "{text}");
    }

    #[test]
    fn bench_diff_per_case_tolerance_overrides_default() {
        let a = bench_doc("grp", false, &[("jittery", 1.0), ("stable", 1.0)]);
        let b = bench_doc("grp", false, &[("jittery", 1.4), ("stable", 1.0)]);
        let mut tols = BTreeMap::new();
        tols.insert("jittery".to_string(), 0.5);
        let report = diff_bench_docs(&a, &b, 0.05, &tols).unwrap();
        assert!(!report.regressed(), "the per-case 50% tolerance must absorb 40%");

        tols.insert("no_such_bench".to_string(), 0.5);
        let err = diff_bench_docs(&a, &b, 0.05, &tols).unwrap_err();
        assert!(format!("{err:#}").contains("no_such_bench"), "{err:#}");
    }

    #[test]
    fn bench_diff_missing_case_fails_gate_and_new_case_does_not() {
        let a = bench_doc("grp", false, &[("kept", 1.0), ("dropped", 1.0)]);
        let b = bench_doc("grp", true, &[("kept", 1.0), ("added", 1.0)]);
        let report = diff_bench_docs(&a, &b, 0.2, &BTreeMap::new()).unwrap();
        assert_eq!(report.only_in_a, vec!["dropped".to_string()]);
        assert_eq!(report.only_in_b, vec!["added".to_string()]);
        assert!(report.quick);
        assert!(report.regressed(), "a disappeared baseline case fails the gate");
        let text = render_bench_diff(&report);
        assert!(text.contains("missing from B"), "{text}");
        assert!(text.contains("quick mode"), "{text}");
    }

    #[test]
    fn bench_diff_rejects_mismatched_groups() {
        let a = bench_doc("grp_a", false, &[("x", 1.0)]);
        let b = bench_doc("grp_b", false, &[("x", 1.0)]);
        assert!(diff_bench_docs(&a, &b, 0.2, &BTreeMap::new()).is_err());
    }
}
