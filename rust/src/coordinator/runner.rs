//! Experiment runner: execute experiments through the plan executor,
//! render the full report (markdown tables + ASCII roofline + paper
//! comparison), and write markdown/SVG/CSV files plus a versioned
//! `run.json` manifest under a reports directory.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::harness::experiments::{ExperimentParams, ExperimentResult};
use crate::roofline::plot::ascii_plot;
use crate::roofline::report::{comparison_table, csv, markdown_table};
use crate::roofline::svg::svg_plot;
use crate::util::fsutil::write_atomic;

use super::manifest::RunManifest;
use super::plan::{self, PlanStats};

/// Paths written for one experiment.
#[derive(Clone, Debug, Default)]
pub struct RunOutput {
    pub markdown: Option<PathBuf>,
    pub svgs: Vec<PathBuf>,
    pub csvs: Vec<PathBuf>,
    /// The versioned `*.run.json` manifest for the run.
    pub manifest: Option<PathBuf>,
}

/// Everything a multi-experiment sweep wrote.
#[derive(Clone, Debug, Default)]
pub struct SweepOutput {
    pub outputs: Vec<RunOutput>,
    /// The sweep-wide `run.json`.
    pub manifest: Option<PathBuf>,
    pub stats: PlanStats,
}

/// Render the complete textual report for an experiment result.
pub fn render_report(result: &ExperimentResult) -> String {
    let mut out = String::new();
    out.push_str(&format!("## {} — {}\n\n", result.id.to_uppercase(), result.title));
    for (title, table) in &result.tables {
        out.push_str(&format!("### {title}\n\n{table}\n"));
    }
    for group in &result.groups {
        let points = group.points();
        out.push_str(&markdown_table(&group.roofline, &points));
        if !group.expectations.is_empty() {
            out.push_str("#### paper vs measured\n\n");
            out.push_str(&comparison_table(&group.roofline, &points, &group.expectations));
        }
        out.push_str("```text\n");
        out.push_str(&ascii_plot(&group.roofline, &points));
        out.push_str("```\n\n");
    }
    for note in &result.notes {
        out.push_str(&format!("> {note}\n\n"));
    }
    out
}

/// Write one experiment result's report files under `out_dir`, recording
/// each file in `manifest`.
fn write_result_files(
    result: &ExperimentResult,
    out_dir: &Path,
    with_svg: bool,
    manifest: &mut RunManifest,
) -> Result<RunOutput> {
    let mut output = RunOutput::default();
    let id = &result.id;

    let md_name = format!("{id}.md");
    let body = render_report(result);
    write_atomic(&out_dir.join(&md_name), &body)?;
    manifest.add_file(&md_name, &body);
    output.markdown = Some(out_dir.join(&md_name));

    for (i, group) in result.groups.iter().enumerate() {
        let points = group.points();
        let suffix = if result.groups.len() > 1 {
            format!("_{i}")
        } else {
            String::new()
        };
        if with_svg {
            let svg_name = format!("{id}{suffix}.svg");
            let svg_body = svg_plot(&group.roofline, &points);
            write_atomic(&out_dir.join(&svg_name), &svg_body)?;
            manifest.add_file(&svg_name, &svg_body);
            output.svgs.push(out_dir.join(svg_name));
        }
        let csv_name = format!("{id}{suffix}.csv");
        let csv_body = csv(&group.roofline, &points);
        write_atomic(&out_dir.join(&csv_name), &csv_body)?;
        manifest.add_file(&csv_name, &csv_body);
        output.csvs.push(out_dir.join(csv_name));
    }
    Ok(output)
}

/// Run one experiment and write its report files + `<id>.run.json`
/// manifest under `out_dir`.
pub fn run_and_write(
    id: &str,
    params: &ExperimentParams,
    out_dir: &Path,
    with_svg: bool,
) -> Result<(ExperimentResult, RunOutput)> {
    let outcome = plan::execute(&[id], params, 1, false)?;
    let result = outcome
        .results
        .into_iter()
        .next()
        .expect("one experiment requested, one result");
    let mut manifest = RunManifest::new(params, &[id], &outcome.cells, &outcome.stats);
    let mut output = write_result_files(&result, out_dir, with_svg, &mut manifest)?;
    let manifest_path = out_dir.join(format!("{id}.run.json"));
    manifest.write(&manifest_path)?;
    output.manifest = Some(manifest_path);
    Ok((result, output))
}

/// Run many experiments as one memoized, parallel plan; write every
/// report plus a sweep-wide `run.json` manifest.
pub fn sweep_and_write(
    ids: &[&str],
    params: &ExperimentParams,
    out_dir: &Path,
    with_svg: bool,
    jobs: usize,
) -> Result<(Vec<ExperimentResult>, SweepOutput)> {
    let outcome = plan::execute(ids, params, jobs, true)?;
    let mut manifest = RunManifest::new(params, ids, &outcome.cells, &outcome.stats);
    let mut sweep = SweepOutput {
        stats: outcome.stats,
        ..Default::default()
    };
    for result in &outcome.results {
        sweep
            .outputs
            .push(write_result_files(result, out_dir, with_svg, &mut manifest)?);
    }
    let manifest_path = out_dir.join("run.json");
    manifest.write(&manifest_path)?;
    sweep.manifest = Some(manifest_path);
    Ok((outcome.results, sweep))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::experiments::run_experiment;
    use crate::testutil::TempDir;

    fn quick_params() -> ExperimentParams {
        ExperimentParams { batch: Some(1), ..Default::default() }
    }

    #[test]
    fn render_f1() {
        let result = run_experiment("f1", &quick_params()).unwrap();
        let report = render_report(&result);
        assert!(report.contains("F1"));
        assert!(report.contains("roofline:"));
        assert!(report.contains("```text"));
    }

    #[test]
    fn run_and_write_produces_files() {
        let dir = TempDir::new("runner");
        let (result, out) = run_and_write("f6", &quick_params(), dir.path(), true).unwrap();
        assert_eq!(result.id, "f6");
        assert!(out.markdown.as_ref().unwrap().exists());
        assert_eq!(out.svgs.len(), 1);
        assert!(out.svgs[0].exists());
        let md = std::fs::read_to_string(out.markdown.unwrap()).unwrap();
        assert!(md.contains("inner_product"));
        assert!(md.contains("paper vs measured"));
    }

    #[test]
    fn run_and_write_emits_validating_manifest() {
        let dir = TempDir::new("runner-manifest");
        let (_, out) = run_and_write("f6", &quick_params(), dir.path(), false).unwrap();
        let path = out.manifest.expect("manifest written");
        let manifest = RunManifest::load(&path).unwrap();
        assert_eq!(manifest.experiments, vec!["f6".to_string()]);
        assert_eq!(manifest.cells.len(), 2);
        // Recorded checksums must match the bytes on disk.
        for f in &manifest.files {
            let body = std::fs::read_to_string(dir.join(&f.path)).unwrap();
            assert_eq!(
                f.checksum,
                crate::coordinator::manifest::FileRecord::from_content(&f.path, &body).checksum,
                "{} checksum drifted",
                f.path
            );
        }
    }

    #[test]
    fn sweep_memoizes_across_experiments() {
        let dir = TempDir::new("sweep");
        let params = quick_params();
        let (results, sweep) =
            sweep_and_write(&["f3", "g1"], &params, dir.path(), false, 2).unwrap();
        assert_eq!(results.len(), 2);
        assert!(sweep.stats.cells_reused >= 3, "stats: {:?}", sweep.stats);
        assert!(
            sweep.stats.cells_simulated < sweep.stats.cells_total,
            "memoization must beat naive expansion: {:?}",
            sweep.stats
        );
        let manifest = RunManifest::load(&sweep.manifest.unwrap()).unwrap();
        assert_eq!(manifest.stats(), sweep.stats);
    }
}
