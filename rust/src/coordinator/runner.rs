//! Experiment runner: execute an experiment, render the full report
//! (markdown tables + ASCII roofline + paper comparison), and write
//! markdown/SVG/CSV files under a reports directory.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::harness::experiments::{run_experiment, ExperimentParams, ExperimentResult};
use crate::roofline::plot::ascii_plot;
use crate::roofline::report::{comparison_table, csv, markdown_table};
use crate::roofline::svg::svg_plot;
use crate::util::fsutil::write_atomic;

/// Paths written for one experiment.
#[derive(Clone, Debug, Default)]
pub struct RunOutput {
    pub markdown: Option<PathBuf>,
    pub svgs: Vec<PathBuf>,
    pub csvs: Vec<PathBuf>,
}

/// Render the complete textual report for an experiment result.
pub fn render_report(result: &ExperimentResult) -> String {
    let mut out = String::new();
    out.push_str(&format!("## {} — {}\n\n", result.id.to_uppercase(), result.title));
    for (title, table) in &result.tables {
        out.push_str(&format!("### {title}\n\n{table}\n"));
    }
    for group in &result.groups {
        let points = group.points();
        out.push_str(&markdown_table(&group.roofline, &points));
        if !group.expectations.is_empty() {
            out.push_str("#### paper vs measured\n\n");
            out.push_str(&comparison_table(&group.roofline, &points, &group.expectations));
        }
        out.push_str("```text\n");
        out.push_str(&ascii_plot(&group.roofline, &points));
        out.push_str("```\n\n");
    }
    for note in &result.notes {
        out.push_str(&format!("> {note}\n\n"));
    }
    out
}

/// Run an experiment and write its report files under `out_dir`.
pub fn run_and_write(
    id: &str,
    params: &ExperimentParams,
    out_dir: &Path,
    with_svg: bool,
) -> Result<(ExperimentResult, RunOutput)> {
    let result = run_experiment(id, params)?;
    let mut output = RunOutput::default();

    let md_path = out_dir.join(format!("{id}.md"));
    write_atomic(&md_path, &render_report(&result))?;
    output.markdown = Some(md_path);

    for (i, group) in result.groups.iter().enumerate() {
        let points = group.points();
        let suffix = if result.groups.len() > 1 {
            format!("_{i}")
        } else {
            String::new()
        };
        if with_svg {
            let svg_path = out_dir.join(format!("{id}{suffix}.svg"));
            write_atomic(&svg_path, &svg_plot(&group.roofline, &points))?;
            output.svgs.push(svg_path);
        }
        let csv_path = out_dir.join(format!("{id}{suffix}.csv"));
        write_atomic(&csv_path, &csv(&group.roofline, &points))?;
        output.csvs.push(csv_path);
    }
    Ok((result, output))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_params() -> ExperimentParams {
        ExperimentParams { batch: Some(1), ..Default::default() }
    }

    #[test]
    fn render_f1() {
        let result = run_experiment("f1", &quick_params()).unwrap();
        let report = render_report(&result);
        assert!(report.contains("F1"));
        assert!(report.contains("roofline:"));
        assert!(report.contains("```text"));
    }

    #[test]
    fn run_and_write_produces_files() {
        let dir = std::env::temp_dir().join(format!("dlr-run-{}", std::process::id()));
        let (result, out) = run_and_write("f6", &quick_params(), &dir, true).unwrap();
        assert_eq!(result.id, "f6");
        assert!(out.markdown.as_ref().unwrap().exists());
        assert_eq!(out.svgs.len(), 1);
        assert!(out.svgs[0].exists());
        let md = std::fs::read_to_string(out.markdown.unwrap()).unwrap();
        assert!(md.contains("inner_product"));
        assert!(md.contains("paper vs measured"));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
