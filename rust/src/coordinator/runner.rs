//! Experiment runner: execute experiments through the plan executor,
//! render the full report (markdown tables + ASCII roofline + paper
//! comparison), and write markdown/SVG/CSV files plus a versioned
//! `run.json` manifest under a reports directory.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::harness::experiments::{ExperimentParams, ExperimentResult};
use crate::roofline::plot::ascii_plot;
use crate::roofline::report::{comparison_table, csv, markdown_table};
use crate::roofline::svg::svg_plot;
use crate::util::fsutil::write_atomic;

use super::manifest::RunManifest;
use super::plan::{self, CellPlan, JobBudget, PlanStats, StoreUsage};
use super::store::CellStore;

/// Paths written for one experiment.
#[derive(Clone, Debug, Default)]
pub struct RunOutput {
    /// The markdown report.
    pub markdown: Option<PathBuf>,
    /// SVG roofline plots (with `--svg`).
    pub svgs: Vec<PathBuf>,
    /// Per-group CSV files.
    pub csvs: Vec<PathBuf>,
    /// The versioned `*.run.json` manifest for the run.
    pub manifest: Option<PathBuf>,
}

/// Everything a multi-experiment sweep wrote.
#[derive(Clone, Debug, Default)]
pub struct SweepOutput {
    /// Per-experiment report files, in request order.
    pub outputs: Vec<RunOutput>,
    /// The sweep-wide `run.json`.
    pub manifest: Option<PathBuf>,
    /// Plan-shape statistics (cells, memoization, skips).
    pub stats: PlanStats,
    /// Persistent cell-cache accounting, when `--cache-dir` was active.
    pub store: Option<StoreUsage>,
    /// The executed plan's cell identities, in plan order — what
    /// `--explain` joins cache fates against (avoids re-expanding).
    pub plan_cells: Vec<CellPlan>,
}

impl SweepOutput {
    /// Every file this sweep wrote — per-experiment reports, plots and
    /// CSVs in output order, then the sweep-wide `run.json`. The serve
    /// daemon uses this as the whitelist of fetchable job artifacts.
    pub fn files(&self) -> Vec<&Path> {
        let mut out: Vec<&Path> = Vec::new();
        for run in &self.outputs {
            if let Some(p) = &run.markdown {
                out.push(p);
            }
            for p in &run.svgs {
                out.push(p);
            }
            for p in &run.csvs {
                out.push(p);
            }
            if let Some(p) = &run.manifest {
                out.push(p);
            }
        }
        if let Some(p) = &self.manifest {
            out.push(p);
        }
        out
    }
}

/// Render the complete textual report for an experiment result.
pub fn render_report(result: &ExperimentResult) -> String {
    let mut out = String::new();
    out.push_str(&format!("## {} — {}\n\n", result.id.to_uppercase(), result.title));
    for (title, table) in &result.tables {
        out.push_str(&format!("### {title}\n\n{table}\n"));
    }
    for group in &result.groups {
        let points = group.points();
        out.push_str(&markdown_table(&group.roofline, &points));
        if !group.expectations.is_empty() {
            out.push_str("#### paper vs measured\n\n");
            out.push_str(&comparison_table(&group.roofline, &points, &group.expectations));
        }
        out.push_str("```text\n");
        out.push_str(&ascii_plot(&group.roofline, &points));
        out.push_str("```\n\n");
    }
    for note in &result.notes {
        out.push_str(&format!("> {note}\n\n"));
    }
    out
}

/// Write one experiment result's report files under `out_dir`, recording
/// each file in `manifest`.
fn write_result_files(
    result: &ExperimentResult,
    out_dir: &Path,
    with_svg: bool,
    manifest: &mut RunManifest,
) -> Result<RunOutput> {
    let mut output = RunOutput::default();
    let id = &result.id;

    let md_name = format!("{id}.md");
    let body = render_report(result);
    write_atomic(&out_dir.join(&md_name), &body)?;
    manifest.add_file(&md_name, &body);
    output.markdown = Some(out_dir.join(&md_name));

    for (i, group) in result.groups.iter().enumerate() {
        let points = group.points();
        let suffix = if result.groups.len() > 1 {
            format!("_{i}")
        } else {
            String::new()
        };
        if with_svg {
            let svg_name = format!("{id}{suffix}.svg");
            let svg_body = svg_plot(&group.roofline, &points);
            write_atomic(&out_dir.join(&svg_name), &svg_body)?;
            manifest.add_file(&svg_name, &svg_body);
            output.svgs.push(out_dir.join(svg_name));
        }
        let csv_name = format!("{id}{suffix}.csv");
        let csv_body = csv(&group.roofline, &points);
        write_atomic(&out_dir.join(&csv_name), &csv_body)?;
        manifest.add_file(&csv_name, &csv_body);
        output.csvs.push(out_dir.join(csv_name));
    }
    Ok(output)
}

/// Run one experiment and write its report files + `<id>.run.json`
/// manifest under `out_dir`.
pub fn run_and_write(
    id: &str,
    params: &ExperimentParams,
    out_dir: &Path,
    with_svg: bool,
) -> Result<(ExperimentResult, RunOutput)> {
    let outcome = plan::execute(&[id], params, 1, false)?;
    let result = outcome
        .results
        .into_iter()
        .next()
        .expect("one experiment requested, one result");
    let mut manifest = RunManifest::new(params, &[id], &outcome.cells, &outcome.stats);
    let mut output = write_result_files(&result, out_dir, with_svg, &mut manifest)?;
    let manifest_path = out_dir.join(format!("{id}.run.json"));
    manifest.write(&manifest_path)?;
    output.manifest = Some(manifest_path);
    Ok((result, output))
}

/// One machine's slice of a grid sweep.
#[derive(Clone, Debug)]
pub struct GridEntry {
    /// Machine name (directory-name-sanitised, uniquified by fingerprint).
    pub machine: String,
    /// The machine's full fingerprint hash.
    pub fingerprint: String,
    /// Subdirectory the machine's reports and `run.json` were written to.
    pub dir: PathBuf,
    /// The machine's sweep output.
    pub output: SweepOutput,
}

/// Everything a multi-machine grid sweep wrote.
#[derive(Clone, Debug, Default)]
pub struct GridOutput {
    /// One entry per deduplicated machine, in request order.
    pub entries: Vec<GridEntry>,
    /// The grid index (`machine_grid.json`) mapping machines to their
    /// per-machine manifests.
    pub index: Option<PathBuf>,
    /// Names of configs skipped because an earlier machine in the list
    /// had the same fingerprint — callers should surface these.
    pub duplicates_skipped: Vec<String>,
}

/// Dedupe a machine list by fingerprint, preserving order. Returns the
/// kept configs and the names of skipped duplicates. Shared by the grid
/// sweep and the `plan` dry-run so a preview expands exactly the
/// machines a sweep will run.
pub fn dedupe_machines(
    machines: &[crate::sim::machine::MachineConfig],
) -> (Vec<&crate::sim::machine::MachineConfig>, Vec<String>) {
    let mut seen = std::collections::HashSet::new();
    let (mut kept, mut skipped) = (Vec::new(), Vec::new());
    for machine in machines {
        if seen.insert(machine.fingerprint()) {
            kept.push(machine);
        } else {
            skipped.push(machine.name.clone());
        }
    }
    (kept, skipped)
}

/// Run the same experiment plan across several machine configs
/// (`sweep --machine a.toml,b.toml`): each machine sweeps into its own
/// subdirectory of `out_dir` (named `<machine>-<fingerprint[..8]>`, so
/// same-named configs cannot collide) with its own `run.json`, and a
/// `machine_grid.json` index ties them together. Cell hashes already key
/// on the machine fingerprint, so per-machine memo tables never mix.
pub fn sweep_grid_and_write(
    ids: &[&str],
    base: &ExperimentParams,
    machines: &[crate::sim::machine::MachineConfig],
    out_dir: &Path,
    with_svg: bool,
    jobs: usize,
) -> Result<GridOutput> {
    sweep_grid_and_write_cached(ids, base, machines, out_dir, with_svg, jobs, None)
}

/// As [`sweep_grid_and_write`], resolving every machine's cells against
/// one shared persistent [`CellStore`]. Cell hashes key on the machine
/// fingerprint, so a single cache directory serves the whole grid
/// without mixing machines.
pub fn sweep_grid_and_write_cached(
    ids: &[&str],
    base: &ExperimentParams,
    machines: &[crate::sim::machine::MachineConfig],
    out_dir: &Path,
    with_svg: bool,
    jobs: usize,
    store: Option<&CellStore>,
) -> Result<GridOutput> {
    let budget = JobBudget::cells(jobs);
    sweep_grid_and_write_budget(ids, base, machines, out_dir, with_svg, budget, store)
}

/// As [`sweep_grid_and_write_cached`], with an explicit [`JobBudget`]
/// so spare `--jobs` capacity flows into intra-cell two-phase workers
/// (`sweep --machine a,b --sim-jobs M`).
pub fn sweep_grid_and_write_budget(
    ids: &[&str],
    base: &ExperimentParams,
    machines: &[crate::sim::machine::MachineConfig],
    out_dir: &Path,
    with_svg: bool,
    budget: JobBudget,
    store: Option<&CellStore>,
) -> Result<GridOutput> {
    use crate::util::json::Json;
    anyhow::ensure!(!machines.is_empty(), "grid sweep needs at least one machine");
    let (kept, skipped) = dedupe_machines(machines);
    let mut grid = GridOutput { duplicates_skipped: skipped, ..Default::default() };
    for machine in kept {
        let fingerprint = machine.fingerprint();
        let safe: String = machine
            .name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .collect();
        let dir = out_dir.join(format!("{safe}-{}", &fingerprint[..8]));
        let params = ExperimentParams { machine: machine.clone(), ..base.clone() };
        let (_, output) = sweep_and_write_budget(ids, &params, &dir, with_svg, budget, store)?;
        grid.entries.push(GridEntry {
            machine: safe,
            fingerprint,
            dir,
            output,
        });
    }
    let index = Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        (
            "machines",
            Json::arr(
                grid.entries
                    .iter()
                    .map(|e| {
                        Json::obj(vec![
                            ("machine", Json::str(e.machine.as_str())),
                            ("fingerprint", Json::str(e.fingerprint.as_str())),
                            (
                                "manifest",
                                Json::str(format!(
                                    "{}/run.json",
                                    e.dir.file_name().unwrap_or_default().to_string_lossy()
                                )),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let index_path = out_dir.join("machine_grid.json");
    write_atomic(&index_path, &index.to_string_pretty())?;
    grid.index = Some(index_path);
    Ok(grid)
}

/// Run many experiments as one memoized, parallel plan; write every
/// report plus a sweep-wide `run.json` manifest.
pub fn sweep_and_write(
    ids: &[&str],
    params: &ExperimentParams,
    out_dir: &Path,
    with_svg: bool,
    jobs: usize,
) -> Result<(Vec<ExperimentResult>, SweepOutput)> {
    sweep_and_write_cached(ids, params, out_dir, with_svg, jobs, None)
}

/// As [`sweep_and_write`], resolving cells against a persistent
/// [`CellStore`] first (`sweep --cache-dir`). A warm store executes zero
/// simulations and still writes byte-identical reports and `run.json` —
/// the manifest deliberately records plan-shape statistics, not cache
/// fates, so cached and uncached runs of the same plan cannot diverge.
pub fn sweep_and_write_cached(
    ids: &[&str],
    params: &ExperimentParams,
    out_dir: &Path,
    with_svg: bool,
    jobs: usize,
    store: Option<&CellStore>,
) -> Result<(Vec<ExperimentResult>, SweepOutput)> {
    sweep_and_write_budget(ids, params, out_dir, with_svg, JobBudget::cells(jobs), store)
}

/// As [`sweep_and_write_cached`], with an explicit [`JobBudget`]: the
/// share of `--jobs` the unique-cell queue cannot absorb is handed to
/// the two-phase simulation engine inside each cell (`--sim-jobs`).
/// Reports and manifests are byte-identical for every budget.
pub fn sweep_and_write_budget(
    ids: &[&str],
    params: &ExperimentParams,
    out_dir: &Path,
    with_svg: bool,
    budget: JobBudget,
    store: Option<&CellStore>,
) -> Result<(Vec<ExperimentResult>, SweepOutput)> {
    let outcome = plan::execute_with_budget(ids, params, budget, true, store)?;
    let mut manifest = RunManifest::new(params, ids, &outcome.cells, &outcome.stats);
    let mut sweep = SweepOutput {
        stats: outcome.stats,
        store: outcome.store,
        plan_cells: outcome.cells.iter().map(|c| c.plan.clone()).collect(),
        ..Default::default()
    };
    for result in &outcome.results {
        sweep
            .outputs
            .push(write_result_files(result, out_dir, with_svg, &mut manifest)?);
    }
    let manifest_path = out_dir.join("run.json");
    manifest.write(&manifest_path)?;
    sweep.manifest = Some(manifest_path);
    Ok((outcome.results, sweep))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::experiments::run_experiment;
    use crate::testutil::TempDir;

    fn quick_params() -> ExperimentParams {
        ExperimentParams { batch: Some(1), ..Default::default() }
    }

    #[test]
    fn render_f1() {
        let result = run_experiment("f1", &quick_params()).unwrap();
        let report = render_report(&result);
        assert!(report.contains("F1"));
        assert!(report.contains("roofline:"));
        assert!(report.contains("```text"));
    }

    #[test]
    fn run_and_write_produces_files() {
        let dir = TempDir::new("runner");
        let (result, out) = run_and_write("f6", &quick_params(), dir.path(), true).unwrap();
        assert_eq!(result.id, "f6");
        assert!(out.markdown.as_ref().unwrap().exists());
        assert_eq!(out.svgs.len(), 1);
        assert!(out.svgs[0].exists());
        let md = std::fs::read_to_string(out.markdown.unwrap()).unwrap();
        assert!(md.contains("inner_product"));
        assert!(md.contains("paper vs measured"));
    }

    #[test]
    fn run_and_write_emits_validating_manifest() {
        let dir = TempDir::new("runner-manifest");
        let (_, out) = run_and_write("f6", &quick_params(), dir.path(), false).unwrap();
        let path = out.manifest.expect("manifest written");
        let manifest = RunManifest::load(&path).unwrap();
        assert_eq!(manifest.experiments, vec!["f6".to_string()]);
        assert_eq!(manifest.cells.len(), 2);
        // Recorded checksums must match the bytes on disk.
        for f in &manifest.files {
            let body = std::fs::read_to_string(dir.join(&f.path)).unwrap();
            assert_eq!(
                f.checksum,
                crate::coordinator::manifest::FileRecord::from_content(&f.path, &body).checksum,
                "{} checksum drifted",
                f.path
            );
        }
    }

    #[test]
    fn grid_sweep_writes_one_dir_per_machine() {
        use crate::sim::machine::MachineConfig;
        let dir = TempDir::new("grid");
        let machines = vec![
            MachineConfig::xeon_6248(),
            MachineConfig::xeon_6248_1s(),
            MachineConfig::xeon_6248(), // duplicate: must be skipped
        ];
        let grid = sweep_grid_and_write(
            &["f6"],
            &quick_params(),
            &machines,
            dir.path(),
            false,
            1,
        )
        .unwrap();
        assert_eq!(grid.entries.len(), 2, "duplicate config must dedupe");
        assert_eq!(grid.duplicates_skipped, vec!["xeon_6248_2s".to_string()]);
        let mut fingerprints = std::collections::HashSet::new();
        for e in &grid.entries {
            assert!(fingerprints.insert(e.fingerprint.clone()));
            let manifest = RunManifest::load(&e.dir.join("run.json")).unwrap();
            assert_eq!(manifest.machine_fingerprint, e.fingerprint);
            assert!(e.dir.join("f6.md").exists());
        }
        let index = std::fs::read_to_string(grid.index.unwrap()).unwrap();
        assert!(index.contains("xeon_6248_1s"), "{index}");
        assert!(index.contains("run.json"));
    }

    #[test]
    fn sweep_memoizes_across_experiments() {
        let dir = TempDir::new("sweep");
        let params = quick_params();
        let (results, sweep) =
            sweep_and_write(&["f3", "g1"], &params, dir.path(), false, 2).unwrap();
        assert_eq!(results.len(), 2);
        assert!(sweep.stats.cells_reused >= 3, "stats: {:?}", sweep.stats);
        assert!(
            sweep.stats.cells_simulated < sweep.stats.cells_total,
            "memoization must beat naive expansion: {:?}",
            sweep.stats
        );
        let manifest = RunManifest::load(&sweep.manifest.unwrap()).unwrap();
        assert_eq!(manifest.stats(), sweep.stats);
    }
}
