//! Versioned run manifests: every sweep/figure run emits a `run.json`
//! describing exactly what was measured — schema version, machine
//! fingerprint, workload params, per-cell W/Q/R results and checksums of
//! every report file written — so a run is a reproducible, diffable
//! artifact rather than a pile of markdown.
//!
//! The manifest is deliberately free of wall-clock time, hostnames and
//! job counts: `--jobs 1` and `--jobs N` sweeps of the same plan must
//! produce byte-identical manifests (asserted by the integration tests).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::harness::experiments::ExperimentParams;
use crate::roofline::point::LevelBytes;
use crate::util::fsutil::write_atomic;
use crate::util::hash::{fnv1a_64, fnv1a_64_hex, hex64};
use crate::util::json::Json;

use super::plan::{ExecutedCell, PlanStats};

/// Current manifest schema version. v2 adds the per-cell `levels` object
/// (per-memory-level traffic for the hierarchical roofline).
/// [`RunManifest::from_json`] also reads v1 documents (cells simply
/// carry no level breakdown) and rejects newer versions.
pub const SCHEMA_VERSION: u64 = 2;

/// One measured cell's identity and W/Q/R results, plus (schema v2) the
/// per-memory-level traffic breakdown.
#[derive(Clone, Debug, PartialEq)]
pub struct CellRecord {
    /// Owning experiment id.
    pub experiment: String,
    /// Kernel display name.
    pub kernel: String,
    /// Scenario preset name.
    pub scenario: String,
    /// Cache-state label (`cold` / `warm`).
    pub cache: String,
    /// Content hash (hex) — the memoization key.
    pub key: String,
    /// Served from the memo table rather than re-simulated.
    pub reused: bool,
    /// Threads the cell ran with.
    pub threads: usize,
    /// Work W (FLOPs, PMU-derived).
    pub work_flops: u64,
    /// Traffic Q (bytes through the IMCs).
    pub traffic_bytes: u64,
    /// Runtime R (modelled seconds).
    pub runtime_seconds: f64,
    /// Per-level bytes (L1/L2/LLC/DRAM-local/DRAM-remote). `None` for
    /// cells read from a v1 manifest.
    pub levels: Option<LevelBytes>,
}

impl CellRecord {
    /// Record an executed plan cell.
    pub fn from_executed(cell: &ExecutedCell) -> CellRecord {
        CellRecord {
            experiment: cell.plan.experiment.clone(),
            kernel: cell.plan.kernel.clone(),
            scenario: cell.plan.scenario.clone(),
            cache: cell.plan.cache.clone(),
            key: hex64(cell.plan.key),
            reused: cell.plan.reused,
            threads: cell.measurement.threads,
            work_flops: cell.measurement.measured.work_flops,
            traffic_bytes: cell.measurement.measured.traffic_bytes,
            runtime_seconds: cell.measurement.runtime.seconds,
            levels: Some(cell.measurement.level_bytes()),
        }
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("experiment", Json::str(self.experiment.as_str())),
            ("kernel", Json::str(self.kernel.as_str())),
            ("scenario", Json::str(self.scenario.as_str())),
            ("cache", Json::str(self.cache.as_str())),
            ("key", Json::str(self.key.as_str())),
            ("reused", Json::Bool(self.reused)),
            ("threads", Json::num(self.threads as f64)),
            ("work_flops", Json::num(self.work_flops as f64)),
            ("traffic_bytes", Json::num(self.traffic_bytes as f64)),
            ("runtime_seconds", Json::num(self.runtime_seconds)),
        ];
        if let Some(l) = &self.levels {
            fields.push(("levels", levels_to_json(l)));
        }
        Json::obj(fields)
    }

    fn from_json(v: &Json) -> Result<CellRecord> {
        Ok(CellRecord {
            experiment: v.expect("experiment")?.as_str()?.to_string(),
            kernel: v.expect("kernel")?.as_str()?.to_string(),
            scenario: v.expect("scenario")?.as_str()?.to_string(),
            cache: v.expect("cache")?.as_str()?.to_string(),
            key: v.expect("key")?.as_str()?.to_string(),
            reused: v.expect("reused")?.as_bool()?,
            threads: v.expect("threads")?.as_usize()?,
            work_flops: v.expect("work_flops")?.as_f64()? as u64,
            traffic_bytes: v.expect("traffic_bytes")?.as_f64()? as u64,
            runtime_seconds: v.expect("runtime_seconds")?.as_f64()?,
            levels: match v.get("levels") {
                Some(Json::Null) | None => None,
                Some(doc) => Some(levels_from_json(doc)?),
            },
        })
    }
}

fn levels_to_json(l: &LevelBytes) -> Json {
    Json::obj(vec![
        ("l1_bytes", Json::num(l.l1)),
        ("l2_bytes", Json::num(l.l2)),
        ("llc_bytes", Json::num(l.llc)),
        ("dram_local_bytes", Json::num(l.dram_local)),
        ("dram_remote_bytes", Json::num(l.dram_remote)),
    ])
}

fn levels_from_json(v: &Json) -> Result<LevelBytes> {
    Ok(LevelBytes {
        l1: v.expect("l1_bytes")?.as_f64()?,
        l2: v.expect("l2_bytes")?.as_f64()?,
        llc: v.expect("llc_bytes")?.as_f64()?,
        dram_local: v.expect("dram_local_bytes")?.as_f64()?,
        dram_remote: v.expect("dram_remote_bytes")?.as_f64()?,
    })
}

/// A report file the run wrote, with its content checksum.
#[derive(Clone, Debug, PartialEq)]
pub struct FileRecord {
    /// Path relative to the run's output directory.
    pub path: String,
    /// File size in bytes.
    pub bytes: u64,
    /// `fnv1a64:<hex>` of the file contents.
    pub checksum: String,
}

impl FileRecord {
    /// Record a file from its (already written) contents.
    pub fn from_content(path: &str, content: &str) -> FileRecord {
        FileRecord {
            path: path.to_string(),
            bytes: content.len() as u64,
            checksum: format!("fnv1a64:{}", fnv1a_64_hex(content.as_bytes())),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("path", Json::str(self.path.as_str())),
            ("bytes", Json::num(self.bytes as f64)),
            ("checksum", Json::str(self.checksum.as_str())),
        ])
    }

    fn from_json(v: &Json) -> Result<FileRecord> {
        Ok(FileRecord {
            path: v.expect("path")?.as_str()?.to_string(),
            bytes: v.expect("bytes")?.as_f64()? as u64,
            checksum: v.expect("checksum")?.as_str()?.to_string(),
        })
    }
}

/// FNV-1a over the parts that identify a plan: machine fingerprint, the
/// experiment ids, and every planned cell key (hex), all in plan order.
/// Both [`RunManifest::plan_hash`] and
/// [`Expansion::plan_hash`](crate::coordinator::plan::Expansion::plan_hash)
/// reduce to this, so a serve job id can be recomputed from the run
/// manifest the job produced.
pub fn plan_hash_parts<I, J>(machine_fingerprint: &str, experiments: I, cell_keys_hex: J) -> u64
where
    I: IntoIterator,
    I::Item: AsRef<str>,
    J: IntoIterator,
    J::Item: AsRef<str>,
{
    let mut buf = String::from(machine_fingerprint);
    for id in experiments {
        buf.push('\n');
        buf.push_str(id.as_ref());
    }
    buf.push_str("\n#");
    for key in cell_keys_hex {
        buf.push('\n');
        buf.push_str(key.as_ref());
    }
    fnv1a_64(buf.as_bytes())
}

/// The versioned description of one run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunManifest {
    /// Manifest schema version (see [`SCHEMA_VERSION`]).
    pub schema_version: u64,
    /// `dlroofline <version>` that wrote the manifest.
    pub generator: String,
    /// Machine fingerprint document (see
    /// [`crate::sim::machine::MachineConfig::fingerprint_json`]).
    pub machine: Json,
    /// Hex hash of the machine document.
    pub machine_fingerprint: String,
    /// Whether the paper's full tensor sizes were used.
    pub full_size: bool,
    /// Batch override, if any.
    pub batch: Option<usize>,
    /// Experiment ids in run order.
    pub experiments: Vec<String>,
    /// How many of those were narrative (non-grid) experiments.
    pub specials: usize,
    /// Cells the machine could not express (not listed in `cells`).
    pub cells_skipped: usize,
    /// Every executed cell with its W/Q/R results.
    pub cells: Vec<CellRecord>,
    /// Checksums of every report file the run wrote.
    pub files: Vec<FileRecord>,
}

impl RunManifest {
    /// Build a manifest for an executed plan (files added separately as
    /// they are written).
    pub fn new(
        params: &ExperimentParams,
        experiments: &[&str],
        cells: &[ExecutedCell],
        stats: &PlanStats,
    ) -> Self {
        RunManifest {
            schema_version: SCHEMA_VERSION,
            generator: format!("dlroofline {}", crate::VERSION),
            machine: params.machine.fingerprint_json(),
            machine_fingerprint: params.machine.fingerprint(),
            full_size: params.full_size,
            batch: params.batch,
            experiments: experiments.iter().map(|s| s.to_string()).collect(),
            specials: stats.specials,
            cells_skipped: stats.cells_skipped,
            cells: cells.iter().map(CellRecord::from_executed).collect(),
            files: Vec::new(),
        }
    }

    /// Record a written report file.
    pub fn add_file(&mut self, rel_path: &str, content: &str) {
        self.files.push(FileRecord::from_content(rel_path, content));
    }

    /// The executed plan's content hash (see [`plan_hash_parts`]) —
    /// recorded as provenance in packed artifacts, and equal to the
    /// submitting plan's
    /// [`Expansion::plan_hash`](crate::coordinator::plan::Expansion::plan_hash).
    pub fn plan_hash(&self) -> u64 {
        plan_hash_parts(
            &self.machine_fingerprint,
            self.experiments.iter(),
            self.cells.iter().map(|c| c.key.as_str()),
        )
    }

    /// Plan statistics recoverable from the manifest itself.
    pub fn stats(&self) -> PlanStats {
        let reused = self.cells.iter().filter(|c| c.reused).count();
        PlanStats {
            experiments: self.experiments.len(),
            specials: self.specials,
            cells_total: self.cells.len() + self.cells_skipped,
            cells_simulated: self.cells.len() - reused,
            cells_reused: reused,
            cells_skipped: self.cells_skipped,
        }
    }

    /// Serialise to the manifest JSON document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::num(self.schema_version as f64)),
            ("generator", Json::str(self.generator.as_str())),
            ("machine", self.machine.clone()),
            ("machine_fingerprint", Json::str(self.machine_fingerprint.as_str())),
            ("full_size", Json::Bool(self.full_size)),
            (
                "batch",
                match self.batch {
                    Some(b) => Json::num(b as f64),
                    None => Json::Null,
                },
            ),
            (
                "experiments",
                Json::arr(self.experiments.iter().map(|s| Json::str(s.as_str())).collect()),
            ),
            ("specials", Json::num(self.specials as f64)),
            ("cells_skipped", Json::num(self.cells_skipped as f64)),
            ("cells", Json::arr(self.cells.iter().map(|c| c.to_json()).collect())),
            ("files", Json::arr(self.files.iter().map(|f| f.to_json()).collect())),
        ])
    }

    /// Parse and validate a manifest document (schema 1..=2).
    pub fn from_json(v: &Json) -> Result<RunManifest> {
        let version = v.expect("schema_version")?.as_f64()? as u64;
        if version == 0 || version > SCHEMA_VERSION {
            bail!(
                "run manifest schema version {version} unsupported (this build reads 1..={SCHEMA_VERSION})"
            );
        }
        let batch = match v.expect("batch")? {
            Json::Null => None,
            other => Some(other.as_usize()?),
        };
        Ok(RunManifest {
            schema_version: version,
            generator: v.expect("generator")?.as_str()?.to_string(),
            machine: v.expect("machine")?.clone(),
            machine_fingerprint: v.expect("machine_fingerprint")?.as_str()?.to_string(),
            full_size: v.expect("full_size")?.as_bool()?,
            batch,
            experiments: v
                .expect("experiments")?
                .as_arr()?
                .iter()
                .map(|e| Ok(e.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            specials: v.expect("specials")?.as_usize()?,
            cells_skipped: v.expect("cells_skipped")?.as_usize()?,
            cells: v
                .expect("cells")?
                .as_arr()?
                .iter()
                .map(CellRecord::from_json)
                .collect::<Result<Vec<_>>>()?,
            files: v
                .expect("files")?
                .as_arr()?
                .iter()
                .map(FileRecord::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }

    /// Serialise (pretty, deterministic — object keys are sorted).
    pub fn to_string_pretty(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Write to `path` atomically.
    pub fn write(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.to_string_pretty())
    }

    /// Load and validate from `path`.
    pub fn load(path: &Path) -> Result<RunManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let doc = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        RunManifest::from_json(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan;

    fn quick() -> ExperimentParams {
        ExperimentParams { batch: Some(1), ..Default::default() }
    }

    fn small_manifest() -> RunManifest {
        let params = quick();
        let outcome = plan::execute(&["f6"], &params, 1, false).unwrap();
        let mut m = RunManifest::new(&params, &["f6"], &outcome.cells, &outcome.stats);
        m.add_file("f6.md", "# report body");
        m
    }

    #[test]
    fn roundtrips_through_json() {
        let m = small_manifest();
        let text = m.to_string_pretty();
        let back = RunManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn rejects_future_schema() {
        let mut doc = small_manifest().to_json();
        if let Json::Obj(map) = &mut doc {
            map.insert("schema_version".into(), Json::num(99.0));
        }
        let err = RunManifest::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("schema version 99"), "{err}");
    }

    #[test]
    fn cells_carry_wqr() {
        let m = small_manifest();
        assert_eq!(m.cells.len(), 2); // f6: cold + warm
        for c in &m.cells {
            assert_eq!(c.experiment, "f6");
            assert_eq!(c.kernel, "inner_product");
            assert!(c.work_flops > 0);
            assert!(c.traffic_bytes > 0);
            assert!(c.runtime_seconds > 0.0);
            assert_eq!(c.key.len(), 16);
        }
        assert_eq!(m.stats().cells_total, 2);
    }

    #[test]
    fn v2_cells_carry_per_level_bytes() {
        let m = small_manifest();
        assert_eq!(m.schema_version, 2);
        for c in &m.cells {
            let levels = c.levels.as_ref().expect("v2 cell has levels");
            assert!(levels.l1 > 0.0, "{}: empty L1 traffic", c.kernel);
            // The DRAM split reconciles with the IMC-counted Q.
            assert!(
                (levels.dram() - c.traffic_bytes as f64).abs() < 1e-3,
                "{}: dram {} vs Q {}",
                c.kernel,
                levels.dram(),
                c.traffic_bytes
            );
        }
    }

    #[test]
    fn reads_v1_manifests_without_levels() {
        // Build a v1 document the way PR 1 wrote them: no `levels` key,
        // schema_version 1.
        let mut doc = small_manifest().to_json();
        if let Json::Obj(map) = &mut doc {
            map.insert("schema_version".into(), Json::num(1.0));
            if let Some(Json::Arr(cells)) = map.get_mut("cells") {
                for cell in cells {
                    if let Json::Obj(c) = cell {
                        c.remove("levels");
                    }
                }
            }
        }
        let back = RunManifest::from_json(&doc).unwrap();
        assert_eq!(back.schema_version, 1);
        assert!(back.cells.iter().all(|c| c.levels.is_none()));
        // W/Q/R survive the migration untouched.
        let orig = small_manifest();
        for (a, b) in back.cells.iter().zip(orig.cells.iter()) {
            assert_eq!(a.work_flops, b.work_flops);
            assert_eq!(a.traffic_bytes, b.traffic_bytes);
            assert_eq!(a.runtime_seconds, b.runtime_seconds);
        }
        // And a migrated document still round-trips.
        let again = RunManifest::from_json(&Json::parse(&back.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back, again);
    }

    #[test]
    fn file_checksums_are_content_hashes() {
        let a = FileRecord::from_content("x.md", "same");
        let b = FileRecord::from_content("y.md", "same");
        let c = FileRecord::from_content("x.md", "different");
        assert_eq!(a.checksum, b.checksum);
        assert_ne!(a.checksum, c.checksum);
        assert!(a.checksum.starts_with("fnv1a64:"));
    }

    #[test]
    fn write_and_load() {
        let dir = crate::testutil::TempDir::new("manifest");
        let path = dir.path().join("run.json");
        let m = small_manifest();
        m.write(&path).unwrap();
        let back = RunManifest::load(&path).unwrap();
        assert_eq!(m, back);
    }
}
