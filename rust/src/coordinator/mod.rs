//! The coordinator: kernel registry, experiment runner and report
//! emission — everything behind the `dlroofline` CLI.

pub mod config;
pub mod registry;
pub mod runner;

pub use registry::KernelRegistry;
pub use runner::{render_report, run_and_write, RunOutput};
