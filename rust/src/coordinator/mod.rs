//! The coordinator: kernel registry, parallel plan executor, versioned
//! run manifests, experiment runner and report emission — everything
//! behind the `dlroofline` CLI.

pub mod config;
pub mod diff;
pub mod manifest;
pub mod plan;
pub mod registry;
pub mod runner;
pub mod store;

pub use diff::{
    diff_bench_docs, diff_manifests, render_bench_diff, render_diff, BenchCaseDrift,
    BenchDiffReport, DiffReport,
};
pub use manifest::{RunManifest, SCHEMA_VERSION};
pub use plan::{job_split, CellFate, JobBudget, PlanOutcome, PlanStats, StoreUsage};
pub use registry::KernelRegistry;
pub use runner::{
    render_report, run_and_write, sweep_and_write, sweep_and_write_budget,
    sweep_and_write_cached, sweep_grid_and_write, sweep_grid_and_write_budget,
    sweep_grid_and_write_cached, GridEntry, GridOutput, RunOutput, SweepOutput,
};
pub use store::{CellStore, GcReport, Lookup, StoreStats, CACHE_ENV, STORE_SCHEMA_VERSION};
