//! The parallel plan executor: expand experiment specs into independent
//! measurement cells, deduplicate them by content hash, simulate the
//! unique cells on a scoped thread pool, and assemble every experiment's
//! result from the memo table.
//!
//! Cells are pure simulations of a fresh [`crate::sim::machine::Machine`]
//! — embarrassingly parallel and fully deterministic — so a `--jobs N`
//! sweep produces bit-identical results (and manifests) to `--jobs 1`;
//! only wall-clock changes. Memoization is by the cell content hash
//! (machine fingerprint × kernel identity × scenario data × cache
//! state), so multi-figure sweeps stop re-simulating shared cells: the
//! `g1` scenario grid reuses all of f3/f4/f5's convolution cells, for
//! example. Cells whose scenario the machine cannot express (e.g.
//! `remote-only` on one socket) are skipped at expansion — counted, not
//! fatal — mirroring the skip in
//! [`crate::harness::spec::ExperimentSpec::run_with`].

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::harness::experiments::{ExperimentParams, ExperimentResult};
use crate::harness::measure::KernelMeasurement;
use crate::harness::spec::{self, ExperimentSpec, SpecKind};

/// A sensible default for `--jobs 0` (auto).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Counters describing what a plan did (or would do).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanStats {
    /// Experiments in the plan.
    pub experiments: usize,
    /// Narrative (non-grid) experiments executed serially.
    pub specials: usize,
    /// Total grid cells across the plan (naive expansion, including
    /// cells the machine cannot express).
    pub cells_total: usize,
    /// Cells actually simulated after content-hash memoization.
    pub cells_simulated: usize,
    /// Cells served from the memo table instead of re-simulating.
    pub cells_reused: usize,
    /// Cells skipped because the machine cannot express their scenario.
    pub cells_skipped: usize,
}

/// Static description of one planned (expressible) cell.
#[derive(Clone, Debug)]
pub struct CellPlan {
    pub experiment: String,
    pub kernel: String,
    pub scenario: String,
    pub cache: String,
    /// Content hash — render with [`crate::util::hash::hex64`] at
    /// display/manifest boundaries.
    pub key: u64,
    /// Whether an earlier cell in the plan already covers this key.
    pub reused: bool,
}

/// One planned cell with its (possibly memoized) measurement.
#[derive(Clone, Debug)]
pub struct ExecutedCell {
    pub plan: CellPlan,
    pub measurement: KernelMeasurement,
}

/// The expansion of a list of experiment ids against fixed params.
pub struct Expansion {
    pub specs: Vec<ExperimentSpec>,
    /// Every expressible planned cell, in deterministic plan order.
    pub cells: Vec<CellPlan>,
    /// Unique cells to simulate: (content hash, representative cell).
    unique: Vec<(u64, spec::Cell)>,
    pub stats: PlanStats,
}

/// Expand `ids` into a deduplicated cell plan. Fails on unknown ids;
/// cells the machine cannot express are counted as skipped, not fatal.
pub fn expand(ids: &[&str], params: &ExperimentParams) -> Result<Expansion> {
    let specs = spec::find_all(ids)?;
    // The machine fingerprint document is identical for every cell of the
    // plan; serialise it once.
    let machine_fp = params.machine.fingerprint_json();

    let mut cells = Vec::new();
    let mut unique: Vec<(u64, spec::Cell)> = Vec::new();
    let mut seen: HashSet<u64> = HashSet::new();
    let mut stats = PlanStats {
        experiments: specs.len(),
        ..Default::default()
    };
    for s in &specs {
        if matches!(s.kind, SpecKind::Special(_)) {
            stats.specials += 1;
        }
        for cell in s.cells() {
            stats.cells_total += 1;
            if cell.scenario.validate(&params.machine).is_err() {
                stats.cells_skipped += 1;
                continue;
            }
            let kernel = cell.kernel.build(params);
            let key = cell.key_parts(&machine_fp, kernel.as_ref());
            let reused = !seen.insert(key);
            if !reused {
                unique.push((key, cell.clone()));
            }
            cells.push(CellPlan {
                experiment: cell.experiment.to_string(),
                kernel: kernel.name(),
                scenario: cell.scenario.name.clone(),
                cache: cell.cache.label().to_string(),
                key,
                reused,
            });
        }
    }
    stats.cells_simulated = unique.len();
    stats.cells_reused = stats.cells_total - stats.cells_skipped - unique.len();
    Ok(Expansion { specs, cells, unique, stats })
}

/// Everything a plan execution produces.
pub struct PlanOutcome {
    /// One result per requested experiment, in request order.
    pub results: Vec<ExperimentResult>,
    /// Every planned cell with its measurement, in plan order.
    pub cells: Vec<ExecutedCell>,
    pub stats: PlanStats,
}

/// Execute a plan: simulate unique cells on `jobs` worker threads
/// (`jobs == 0` picks [`default_jobs`]), then assemble every experiment
/// from the memo table. Specials run serially on the calling thread.
///
/// With `tolerate_special_failures`, a narrative experiment that cannot
/// run on this machine (e.g. `m1` on one socket) yields a placeholder
/// result carrying the error as a note instead of aborting the plan —
/// what a multi-experiment sweep wants; a single-figure run wants the
/// error.
pub fn execute(
    ids: &[&str],
    params: &ExperimentParams,
    jobs: usize,
    tolerate_special_failures: bool,
) -> Result<PlanOutcome> {
    let expansion = expand(ids, params)?;
    let jobs = if jobs == 0 { default_jobs() } else { jobs };

    let memo = simulate_unique(&expansion.unique, params, jobs)?;

    // Assemble experiments in request order from the memo table. The
    // grid walk in `run_with` visits cells in exactly the order `expand`
    // planned them (same expansion, same skip filter), so a cursor over
    // the plan's cell list replaces any key recomputation; the identity
    // check turns a future divergence into an error instead of silently
    // mixing up cells.
    let mut results = Vec::new();
    let mut cursor = 0usize;
    for s in &expansion.specs {
        let outcome = s.run_with(params, &mut |cell: &spec::Cell| {
            let plan = expansion
                .cells
                .get(cursor)
                .ok_or_else(|| anyhow!("plan exhausted at cell {cursor} (planner bug)"))?;
            if plan.experiment != cell.experiment
                || plan.scenario != cell.scenario.name
                || plan.cache != cell.cache.label()
            {
                bail!(
                    "plan/assembly order diverged at cell {cursor}: planned \
                     {}/{}/{}, assembling {}/{}/{} (planner bug)",
                    plan.experiment,
                    plan.scenario,
                    plan.cache,
                    cell.experiment,
                    cell.scenario.name,
                    cell.cache.label()
                );
            }
            cursor += 1;
            memo.get(&plan.key)
                .cloned()
                .ok_or_else(|| anyhow!("cell {:#x} missing from memo table (planner bug)", plan.key))
        });
        match (outcome, &s.kind) {
            (Ok(r), _) => results.push(r),
            (Err(e), SpecKind::Special(_)) if tolerate_special_failures => {
                results.push(ExperimentResult {
                    id: s.id.into(),
                    title: s.title.into(),
                    notes: vec![format!("skipped on this machine: {e:#}")],
                    ..Default::default()
                });
            }
            (Err(e), _) => return Err(e),
        }
    }

    // Attach measurements to the plan's cell list.
    let cells = expansion
        .cells
        .iter()
        .map(|plan| ExecutedCell {
            plan: plan.clone(),
            measurement: memo.get(&plan.key).expect("planned cell measured").clone(),
        })
        .collect();

    Ok(PlanOutcome { results, cells, stats: expansion.stats })
}

/// Simulate each unique cell exactly once, in parallel.
fn simulate_unique(
    unique: &[(u64, spec::Cell)],
    params: &ExperimentParams,
    jobs: usize,
) -> Result<HashMap<u64, KernelMeasurement>> {
    let mut memo = HashMap::with_capacity(unique.len());
    if unique.is_empty() {
        return Ok(memo);
    }
    let workers = jobs.clamp(1, unique.len());
    if workers == 1 {
        for (key, cell) in unique {
            memo.insert(*key, cell.simulate(params)?);
        }
        return Ok(memo);
    }

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<KernelMeasurement>>>> =
        (0..unique.len()).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let idx = next.fetch_add(1, Ordering::Relaxed);
                if idx >= unique.len() {
                    break;
                }
                let outcome = unique[idx].1.simulate(params);
                *slots[idx].lock().unwrap() = Some(outcome);
            });
        }
    });
    for (i, slot) in slots.into_iter().enumerate() {
        let outcome = slot
            .into_inner()
            .unwrap()
            .ok_or_else(|| anyhow!("worker never reached cell {i} (planner bug)"))?;
        memo.insert(unique[i].0, outcome?);
    }
    Ok(memo)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> ExperimentParams {
        ExperimentParams { batch: Some(1), ..Default::default() }
    }

    #[test]
    fn expand_dedups_shared_cells() {
        let params = quick();
        let e = expand(&["f3", "f4", "f5", "g1"], &params).unwrap();
        // f3/f4/f5 contribute 9 conv cells that reappear inside g1's
        // 18-cell grid: naive 27, unique 18.
        assert_eq!(e.stats.cells_total, 27);
        assert_eq!(e.stats.cells_simulated, 18);
        assert_eq!(e.stats.cells_reused, 9);
        assert_eq!(e.stats.cells_skipped, 0);
        assert_eq!(e.stats.experiments, 4);
        assert_eq!(e.stats.specials, 0);
        // The reused flags mark exactly the g1 duplicates.
        assert_eq!(e.cells.iter().filter(|c| c.reused).count(), 9);
    }

    #[test]
    fn expand_skips_inexpressible_cells() {
        // g1's remote-only column (3 kernels) cannot run on one socket:
        // skipped and counted, not fatal.
        let mut params = quick();
        params.machine = crate::sim::machine::MachineConfig::xeon_6248_1s();
        let e = expand(&["g1"], &params).unwrap();
        assert_eq!(e.stats.cells_total, 18);
        assert_eq!(e.stats.cells_skipped, 3);
        assert_eq!(e.stats.cells_simulated, 15);
        assert!(e.cells.iter().all(|c| c.scenario != "remote-only"));
    }

    #[test]
    fn expand_rejects_unknown_id() {
        assert!(expand(&["f3", "zz"], &quick()).is_err());
    }

    #[test]
    fn execute_serial_matches_direct_run() {
        let params = quick();
        let direct = crate::harness::experiments::run_experiment("f6", &params).unwrap();
        let outcome = execute(&["f6"], &params, 1, false).unwrap();
        assert_eq!(outcome.results.len(), 1);
        let planned = &outcome.results[0];
        assert_eq!(planned.id, direct.id);
        assert_eq!(planned.groups.len(), direct.groups.len());
        for (a, b) in planned.groups[0]
            .measurements
            .iter()
            .zip(direct.groups[0].measurements.iter())
        {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.measured.work_flops, b.measured.work_flops);
            assert_eq!(a.measured.traffic_bytes, b.measured.traffic_bytes);
            assert_eq!(a.runtime.seconds.to_bits(), b.runtime.seconds.to_bits());
        }
    }

    #[test]
    fn parallel_execution_is_deterministic() {
        let params = quick();
        let serial = execute(&["f3", "f6"], &params, 1, false).unwrap();
        let parallel = execute(&["f3", "f6"], &params, 4, false).unwrap();
        assert_eq!(serial.stats, parallel.stats);
        assert_eq!(serial.cells.len(), parallel.cells.len());
        for (a, b) in serial.cells.iter().zip(parallel.cells.iter()) {
            assert_eq!(a.plan.key, b.plan.key);
            assert_eq!(
                a.measurement.runtime.seconds.to_bits(),
                b.measurement.runtime.seconds.to_bits(),
                "cell {} diverged between --jobs 1 and --jobs 4",
                a.plan.key
            );
        }
    }

    #[test]
    fn specials_flow_through_plan() {
        let outcome = execute(&["p1", "v1"], &quick(), 2, false).unwrap();
        assert_eq!(outcome.results.len(), 2);
        assert_eq!(outcome.stats.specials, 2);
        assert_eq!(outcome.stats.cells_total, 0);
        assert!(!outcome.results[0].tables.is_empty());
    }

    #[test]
    fn tolerant_execute_survives_impossible_special() {
        // m1 needs two sockets; tolerant mode records the skip, strict
        // mode propagates the error.
        let mut params = quick();
        params.machine = crate::sim::machine::MachineConfig::xeon_6248_1s();
        assert!(execute(&["m1"], &params, 1, false).is_err());
        let outcome = execute(&["f3", "m1"], &params, 1, true).unwrap();
        assert_eq!(outcome.results.len(), 2);
        assert!(outcome.results[1]
            .notes
            .iter()
            .any(|n| n.contains("skipped on this machine")));
        // The runnable experiment still produced real groups.
        assert!(!outcome.results[0].groups.is_empty());
    }
}
