//! The parallel plan executor: expand experiment specs into independent
//! measurement cells, deduplicate them by content hash, simulate the
//! unique cells on a scoped thread pool, and assemble every experiment's
//! result from the memo table.
//!
//! Cells are pure simulations of a fresh [`crate::sim::machine::Machine`]
//! — embarrassingly parallel and fully deterministic — so a `--jobs N`
//! sweep produces bit-identical results (and manifests) to `--jobs 1`;
//! only wall-clock changes. When the unique-cell queue is shallower
//! than the budget, the spare workers flow *into* the cells: the
//! [`JobBudget`]/[`job_split`] rule hands each cell up to `--sim-jobs`
//! phase-A workers of the two-phase simulation engine (§Perf step 7)
//! while keeping `cell workers × sim workers ≤ --jobs` — so the
//! biggest cells no longer pin the sweep's wall-clock to one core, and
//! the bit-identity guarantee extends across every budget. Memoization is by the cell content hash
//! (machine fingerprint × kernel identity × scenario data × cache
//! state), so multi-figure sweeps stop re-simulating shared cells: the
//! `g1` scenario grid reuses all of f3/f4/f5's convolution cells, for
//! example. Cells whose scenario the machine cannot express (e.g.
//! `remote-only` on one socket) are skipped at expansion — counted, not
//! fatal — mirroring the skip in
//! [`crate::harness::spec::ExperimentSpec::run_with`].
//!
//! With a persistent [`CellStore`] ([`execute_with_store`]), the memo
//! table additionally survives the process: unique cells are resolved
//! against the on-disk store first, so a repeated sweep only simulates
//! cells the plan edit actually changed.
//!
//! ```
//! use dlroofline::coordinator::plan;
//! use dlroofline::harness::experiments::ExperimentParams;
//!
//! // Expanding a plan builds kernels and hashes cells but simulates
//! // nothing — `dlroofline plan` is this call plus a table.
//! let params = ExperimentParams { batch: Some(1), ..Default::default() };
//! let e = plan::expand(&["f3", "g1"], &params).unwrap();
//! assert_eq!(e.stats.cells_total, 21);
//! // f3's three cells reappear inside g1's grid and memoize away.
//! assert_eq!(e.stats.cells_reused, 3);
//! assert_eq!(e.stats.cells_simulated, 18);
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::harness::experiments::{ExperimentParams, ExperimentResult};
use crate::harness::measure::KernelMeasurement;
use crate::harness::spec::{self, ExperimentSpec, SpecKind};
use crate::sim::machine::Machine;

use super::store::{CellStore, Lookup};

/// A sensible default for `--jobs 0` (auto).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Worker budget for one plan execution: cell-level workers plus the
/// intra-cell workers of the set-sharded simulation engine.
///
/// The two dimensions share one machine: [`job_split`] guarantees
/// `cell workers × sim workers` never exceeds the `jobs` budget, so
/// `--jobs × --sim-jobs` cannot oversubscribe cores. Results are
/// bit-identical for every budget — only wall-clock changes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JobBudget {
    /// Cell-level worker threads (`0` = auto ⇒ [`default_jobs`]).
    pub jobs: usize,
    /// Intra-cell simulation workers per cell
    /// ([`crate::harness::measure_kernel_sharded`]): `1` pins the
    /// serial batched pipeline, `N ≥ 2` selects the set-sharded engine
    /// with up to `N` phase-A workers and `N` phase-B set shards per
    /// cell, `0` = auto (each cell worker's share of the `jobs` budget
    /// — big cells get intra-cell workers exactly when the cell queue
    /// is shallow).
    pub sim_jobs: usize,
}

impl JobBudget {
    /// `jobs` cell workers, serial per-cell simulation — the behaviour
    /// of the plain `jobs: usize` entry points.
    pub fn cells(jobs: usize) -> JobBudget {
        JobBudget { jobs, sim_jobs: 1 }
    }
}

/// Split a shared worker budget between cell-level and intra-cell
/// parallelism for a queue of `cells` pending simulations. Returns
/// `(cell_workers, sim_workers)` with both ≥ 1 and
/// `cell_workers × sim_workers ≤ max(jobs, 1)`.
///
/// Cell-level parallelism wins first (it has no coordination cost);
/// whatever budget the queue cannot absorb — the queue is shallower
/// than `jobs` — is handed to the two-phase engine inside each cell,
/// capped at `sim_jobs` (`0` = uncapped auto).
pub fn job_split(jobs: usize, sim_jobs: usize, cells: usize) -> (usize, usize) {
    let jobs = jobs.max(1);
    let cell_workers = jobs.min(cells.max(1));
    let spare = jobs / cell_workers;
    let cap = if sim_jobs == 0 { spare } else { sim_jobs };
    (cell_workers, spare.min(cap).max(1))
}

/// Counters describing what a plan did (or would do).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanStats {
    /// Experiments in the plan.
    pub experiments: usize,
    /// Narrative (non-grid) experiments executed serially.
    pub specials: usize,
    /// Total grid cells across the plan (naive expansion, including
    /// cells the machine cannot express).
    pub cells_total: usize,
    /// Cells actually simulated after content-hash memoization.
    pub cells_simulated: usize,
    /// Cells served from the memo table instead of re-simulating.
    pub cells_reused: usize,
    /// Cells skipped because the machine cannot express their scenario.
    pub cells_skipped: usize,
}

/// Static description of one planned (expressible) cell.
#[derive(Clone, Debug)]
pub struct CellPlan {
    /// Owning experiment id.
    pub experiment: String,
    /// Kernel display name.
    pub kernel: String,
    /// Scenario preset name.
    pub scenario: String,
    /// Cache-state label (`cold` / `warm`).
    pub cache: String,
    /// Content hash — render with [`crate::util::hash::hex64`] at
    /// display/manifest boundaries.
    pub key: u64,
    /// Whether an earlier cell in the plan already covers this key.
    pub reused: bool,
}

/// One planned cell with its (possibly memoized) measurement.
#[derive(Clone, Debug)]
pub struct ExecutedCell {
    /// The planned cell's identity.
    pub plan: CellPlan,
    /// The cell's (possibly memoized) measurement.
    pub measurement: KernelMeasurement,
}

/// The expansion of a list of experiment ids against fixed params.
pub struct Expansion {
    /// Resolved experiment specs, in request order.
    pub specs: Vec<ExperimentSpec>,
    /// Every expressible planned cell, in deterministic plan order.
    pub cells: Vec<CellPlan>,
    /// Unique cells to simulate: (content hash, representative cell).
    unique: Vec<(u64, spec::Cell)>,
    /// Counters describing the expansion.
    pub stats: PlanStats,
}

impl Expansion {
    /// The unique cells the plan would simulate, in plan order — one
    /// `(content hash, cell)` pair per non-`reused` entry of
    /// [`Expansion::cells`]. The parity suite uses this to seed a cell
    /// store with independently produced measurements.
    pub fn unique_cells(&self) -> &[(u64, spec::Cell)] {
        &self.unique
    }

    /// Content hash identifying this plan: machine fingerprint ×
    /// experiment ids × every planned cell key, in plan order. The serve
    /// daemon derives job ids from it, and it agrees with
    /// [`RunManifest::plan_hash`](crate::coordinator::manifest::RunManifest::plan_hash)
    /// for the run this plan produces, so packed-artifact provenance and
    /// job ids name the same thing.
    pub fn plan_hash(&self, machine_fingerprint: &str) -> u64 {
        crate::coordinator::manifest::plan_hash_parts(
            machine_fingerprint,
            self.specs.iter().map(|s| s.id),
            self.cells.iter().map(|c| crate::util::hash::hex64(c.key)),
        )
    }
}

/// Expand `ids` into a deduplicated cell plan. Fails on unknown ids;
/// cells the machine cannot express are counted as skipped, not fatal.
pub fn expand(ids: &[&str], params: &ExperimentParams) -> Result<Expansion> {
    expand_specs(spec::find_all(ids)?, params)
}

/// Expand already-resolved specs into a deduplicated cell plan — the
/// entry point for synthetic specs that never appear in the registry,
/// such as the tuning lattice's variant grid
/// ([`crate::tune::TuningLattice::to_spec`]). Registry ids go through
/// [`expand`], which resolves them and lands here.
///
/// Memoization is guarded: two cells may share a content hash only if
/// they agree on display identity (kernel, scenario, cache). A
/// disagreement means the content hash under-describes the cell — e.g.
/// a tuning knob that changes the trace but was left out of the hashed
/// kernel identity — and silently sharing one measurement between the
/// two would corrupt every ranking downstream, so expansion fails
/// loudly instead.
pub fn expand_specs(specs: Vec<ExperimentSpec>, params: &ExperimentParams) -> Result<Expansion> {
    // The machine fingerprint document is identical for every cell of the
    // plan; serialise it once.
    let machine_fp = params.machine.fingerprint_json();

    let mut cells: Vec<CellPlan> = Vec::new();
    let mut unique: Vec<(u64, spec::Cell)> = Vec::new();
    // Content hash → index of the first planned cell with that key, so
    // a reuse can be identity-checked against its representative.
    let mut seen: HashMap<u64, usize> = HashMap::new();
    let mut stats = PlanStats {
        experiments: specs.len(),
        ..Default::default()
    };
    for s in &specs {
        if matches!(s.kind, SpecKind::Special(_)) {
            stats.specials += 1;
        }
        for cell in s.cells() {
            stats.cells_total += 1;
            if cell.scenario.validate(&params.machine).is_err() {
                stats.cells_skipped += 1;
                continue;
            }
            let kernel = cell.kernel.build(params);
            let key = cell.key_parts(&machine_fp, kernel.as_ref());
            let name = kernel.name();
            let scenario = cell.scenario.name.clone();
            let cache = cell.cache.label().to_string();
            let reused = match seen.get(&key) {
                Some(&first) => {
                    check_reuse_identity(&cells[first], &name, &scenario, &cache)?;
                    true
                }
                None => {
                    seen.insert(key, cells.len());
                    unique.push((key, cell.clone()));
                    false
                }
            };
            cells.push(CellPlan {
                experiment: cell.experiment.to_string(),
                kernel: name,
                scenario,
                cache,
                key,
                reused,
            });
        }
    }
    stats.cells_simulated = unique.len();
    stats.cells_reused = stats.cells_total - stats.cells_skipped - unique.len();
    Ok(Expansion { specs, cells, unique, stats })
}

/// The memoization identity guard: a planned cell may reuse `first`'s
/// measurement only if both agree on kernel, scenario and cache-state
/// identity. Anything else is a content-hash collision — two distinct
/// cells whose hashed identity documents came out equal — and must fail
/// the expansion rather than silently serve one cell's measurement as
/// the other's.
fn check_reuse_identity(
    first: &CellPlan,
    kernel: &str,
    scenario: &str,
    cache: &str,
) -> Result<()> {
    if first.kernel == kernel && first.scenario == scenario && first.cache == cache {
        return Ok(());
    }
    bail!(
        "cell content-hash collision at {:#018x}: {}/{}/{} (experiment {}) and \
         {kernel}/{scenario}/{cache} hash identically but are different cells — \
         a knob that changes the simulation is missing from the hashed kernel \
         identity (planner bug)",
        first.key,
        first.kernel,
        first.scenario,
        first.cache,
        first.experiment,
    )
}

/// How one unique cell was resolved against the persistent store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellFate {
    /// Served from a valid on-disk record — not simulated.
    Hit,
    /// No record existed — simulated and written back.
    Miss,
    /// A record existed but was unusable (corrupt, wrong schema version,
    /// or identity mismatch) — simulated and overwritten.
    Stale,
}

impl CellFate {
    /// Short display label for `--explain` tables.
    pub fn label(self) -> &'static str {
        match self {
            CellFate::Hit => "hit",
            CellFate::Miss => "miss",
            CellFate::Stale => "stale",
        }
    }
}

/// What the persistent cell store contributed to one execution.
#[derive(Clone, Debug, Default)]
pub struct StoreUsage {
    /// Unique cells served from disk instead of simulating.
    pub hits: usize,
    /// Unique cells whose on-disk record was unusable.
    pub stale: usize,
    /// Unique cells actually simulated this run (misses + stale).
    pub simulated: usize,
    /// Per-unique-cell fate, keyed by cell content hash (`--explain`).
    pub fates: HashMap<u64, CellFate>,
    /// Cache *writes* (records or index) that failed. Write failures
    /// never fail the run — a read-only or full cache directory costs
    /// future hits, not this sweep's results.
    pub write_errors: usize,
    /// The first write failure, for surfacing to the user.
    pub first_write_error: Option<String>,
}

/// Everything a plan execution produces.
pub struct PlanOutcome {
    /// One result per requested experiment, in request order.
    pub results: Vec<ExperimentResult>,
    /// Every planned cell with its measurement, in plan order.
    pub cells: Vec<ExecutedCell>,
    /// Plan-shape statistics (identical between cold- and warm-cache
    /// executions of the same plan — the manifest records these).
    pub stats: PlanStats,
    /// Persistent-store accounting, when a store was supplied.
    pub store: Option<StoreUsage>,
}

/// Execute a plan: simulate unique cells on `jobs` worker threads
/// (`jobs == 0` picks [`default_jobs`]), then assemble every experiment
/// from the memo table. Specials run serially on the calling thread.
///
/// With `tolerate_special_failures`, a narrative experiment that cannot
/// run on this machine (e.g. `m1` on one socket) yields a placeholder
/// result carrying the error as a note instead of aborting the plan —
/// what a multi-experiment sweep wants; a single-figure run wants the
/// error.
pub fn execute(
    ids: &[&str],
    params: &ExperimentParams,
    jobs: usize,
    tolerate_special_failures: bool,
) -> Result<PlanOutcome> {
    execute_with_store(ids, params, jobs, tolerate_special_failures, None)
}

/// As [`execute_with_store`], with an explicit [`JobBudget`] so the
/// unused share of the `jobs` budget flows into intra-cell two-phase
/// workers (`sweep --jobs N --sim-jobs M` lands here). Outputs are
/// bit-identical for every budget.
pub fn execute_with_budget(
    ids: &[&str],
    params: &ExperimentParams,
    budget: JobBudget,
    tolerate_special_failures: bool,
    store: Option<&CellStore>,
) -> Result<PlanOutcome> {
    execute_impl(expand(ids, params)?, params, budget, tolerate_special_failures, store)
}

/// As [`execute_with_budget`], for already-resolved specs (see
/// [`expand_specs`]): the tuning lattice drives its synthetic variant
/// grid through the same memoizing executor and cell store here, so a
/// warm re-tune executes zero simulations.
pub fn execute_specs_with_budget(
    specs: Vec<ExperimentSpec>,
    params: &ExperimentParams,
    budget: JobBudget,
    tolerate_special_failures: bool,
    store: Option<&CellStore>,
) -> Result<PlanOutcome> {
    execute_impl(
        expand_specs(specs, params)?,
        params,
        budget,
        tolerate_special_failures,
        store,
    )
}

/// As [`execute`], resolving unique cells against a persistent
/// [`CellStore`] first: valid records are served from disk (zero
/// simulation), everything else is simulated and written back, and the
/// outcome's `store` field reports per-cell hit/miss/stale fates.
///
/// The store is *invisible* in the results: a served measurement is
/// bit-identical to the simulation that produced it
/// ([`KernelMeasurement::to_json`] round-trips losslessly), so reports
/// and manifests come out byte-identical whether the cache was cold,
/// warm, or absent. Served records are additionally identity-checked
/// (kernel, scenario, cache state) against the plan, so even a content
/// hash collision cannot substitute the wrong cell.
pub fn execute_with_store(
    ids: &[&str],
    params: &ExperimentParams,
    jobs: usize,
    tolerate_special_failures: bool,
    store: Option<&CellStore>,
) -> Result<PlanOutcome> {
    execute_impl(
        expand(ids, params)?,
        params,
        JobBudget::cells(jobs),
        tolerate_special_failures,
        store,
    )
}

fn execute_impl(
    expansion: Expansion,
    params: &ExperimentParams,
    budget: JobBudget,
    tolerate_special_failures: bool,
    store: Option<&CellStore>,
) -> Result<PlanOutcome> {
    let budget = JobBudget {
        jobs: if budget.jobs == 0 { default_jobs() } else { budget.jobs },
        ..budget
    };

    let mut usage = store.map(|_| StoreUsage::default());
    let memo: HashMap<u64, KernelMeasurement> = if let (Some(st), Some(u)) =
        (store, usage.as_mut())
    {
        // The i-th non-reused planned cell is exactly unique[i] (same
        // expansion pass), which gives us the display identity to check
        // served records against.
        let mut memo = HashMap::with_capacity(expansion.unique.len());
        let mut to_sim: Vec<(u64, spec::Cell)> = Vec::new();
        let mut hit_keys: Vec<u64> = Vec::new();
        let plans = expansion.cells.iter().filter(|c| !c.reused);
        for ((key, cell), plan) in expansion.unique.iter().zip(plans) {
            let fate = match st.lookup(*key) {
                Lookup::Hit(m)
                    if m.kernel == plan.kernel
                        && m.scenario == plan.scenario
                        && m.cache_state.label() == plan.cache =>
                {
                    memo.insert(*key, *m);
                    u.hits += 1;
                    hit_keys.push(*key);
                    CellFate::Hit
                }
                // A parseable record whose identity disagrees with the
                // plan: hash collision or a foreign file — never serve it.
                Lookup::Hit(_) | Lookup::Stale(_) => {
                    u.stale += 1;
                    to_sim.push((*key, cell.clone()));
                    CellFate::Stale
                }
                Lookup::Miss => {
                    to_sim.push((*key, cell.clone()));
                    CellFate::Miss
                }
            };
            u.fates.insert(*key, fate);
        }
        u.simulated = to_sim.len();
        let simulated = simulate_unique(&to_sim, params, budget)?;
        // Cache writes are best-effort: a read-only or full cache
        // directory must not fail a sweep whose simulations succeeded.
        let note_write_error = |u: &mut StoreUsage, e: anyhow::Error| {
            u.write_errors += 1;
            if u.first_write_error.is_none() {
                u.first_write_error = Some(format!("{e:#}"));
            }
        };
        for (key, m) in &simulated {
            if let Err(e) = st.insert(*key, m) {
                note_write_error(u, e);
            }
        }
        st.mark_hits(&hit_keys);
        if let Err(e) = st.save_index() {
            note_write_error(u, e);
        }
        memo.extend(simulated);
        memo
    } else {
        simulate_unique(&expansion.unique, params, budget)?
    };

    // Assemble experiments in request order from the memo table. The
    // grid walk in `run_with` visits cells in exactly the order `expand`
    // planned them (same expansion, same skip filter), so a cursor over
    // the plan's cell list replaces any key recomputation; the identity
    // check turns a future divergence into an error instead of silently
    // mixing up cells.
    let mut results = Vec::new();
    let mut cursor = 0usize;
    for s in &expansion.specs {
        let outcome = s.run_with(params, &mut |cell: &spec::Cell| {
            let plan = expansion
                .cells
                .get(cursor)
                .ok_or_else(|| anyhow!("plan exhausted at cell {cursor} (planner bug)"))?;
            if plan.experiment != cell.experiment
                || plan.scenario != cell.scenario.name
                || plan.cache != cell.cache.label()
            {
                bail!(
                    "plan/assembly order diverged at cell {cursor}: planned \
                     {}/{}/{}, assembling {}/{}/{} (planner bug)",
                    plan.experiment,
                    plan.scenario,
                    plan.cache,
                    cell.experiment,
                    cell.scenario.name,
                    cell.cache.label()
                );
            }
            cursor += 1;
            memo.get(&plan.key)
                .cloned()
                .ok_or_else(|| anyhow!("cell {:#x} missing from memo table (planner bug)", plan.key))
        });
        match (outcome, &s.kind) {
            (Ok(r), _) => results.push(r),
            (Err(e), SpecKind::Special(_)) if tolerate_special_failures => {
                results.push(ExperimentResult {
                    id: s.id.into(),
                    title: s.title.into(),
                    notes: vec![format!("skipped on this machine: {e:#}")],
                    ..Default::default()
                });
            }
            (Err(e), _) => return Err(e),
        }
    }

    // Attach measurements to the plan's cell list.
    let cells = expansion
        .cells
        .iter()
        .map(|plan| ExecutedCell {
            plan: plan.clone(),
            measurement: memo.get(&plan.key).expect("planned cell measured").clone(),
        })
        .collect();

    Ok(PlanOutcome { results, cells, stats: expansion.stats, store: usage })
}

/// Simulate each unique cell exactly once, in parallel, splitting the
/// budget between cell workers and intra-cell sharded-engine workers
/// ([`job_split`] — derived from the *actual* queue depth, so a mostly
/// cache-served sweep still hands its few misses intra-cell workers).
///
/// Every worker (and the serial path) builds **one** [`Machine`] and
/// reuses it across all the cells it claims
/// ([`spec::Cell::simulate_jobs_on`] resets it per measurement): the
/// simulator's cache arrays, survivor-stream pools and phase-A scratch
/// buffers are recycled instead of reallocated per cell — the
/// allocation churn that showed up on warm tune-lattice sweeps.
fn simulate_unique(
    unique: &[(u64, spec::Cell)],
    params: &ExperimentParams,
    budget: JobBudget,
) -> Result<HashMap<u64, KernelMeasurement>> {
    let mut memo = HashMap::with_capacity(unique.len());
    if unique.is_empty() {
        return Ok(memo);
    }
    let (workers, sim_jobs) = job_split(budget.jobs, budget.sim_jobs, unique.len());
    if workers == 1 {
        let mut machine = Machine::new(params.machine.clone());
        for (key, cell) in unique {
            memo.insert(*key, cell.simulate_jobs_on(&mut machine, params, sim_jobs)?);
        }
        return Ok(memo);
    }

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<KernelMeasurement>>>> =
        (0..unique.len()).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut machine = Machine::new(params.machine.clone());
                loop {
                    let idx = next.fetch_add(1, Ordering::Relaxed);
                    if idx >= unique.len() {
                        break;
                    }
                    let outcome = unique[idx].1.simulate_jobs_on(&mut machine, params, sim_jobs);
                    *slots[idx].lock().unwrap() = Some(outcome);
                }
            });
        }
    });
    for (i, slot) in slots.into_iter().enumerate() {
        let outcome = slot
            .into_inner()
            .unwrap()
            .ok_or_else(|| anyhow!("worker never reached cell {i} (planner bug)"))?;
        memo.insert(unique[i].0, outcome?);
    }
    Ok(memo)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> ExperimentParams {
        ExperimentParams { batch: Some(1), ..Default::default() }
    }

    #[test]
    fn expand_dedups_shared_cells() {
        let params = quick();
        let e = expand(&["f3", "f4", "f5", "g1"], &params).unwrap();
        // f3/f4/f5 contribute 9 conv cells that reappear inside g1's
        // 18-cell grid: naive 27, unique 18.
        assert_eq!(e.stats.cells_total, 27);
        assert_eq!(e.stats.cells_simulated, 18);
        assert_eq!(e.stats.cells_reused, 9);
        assert_eq!(e.stats.cells_skipped, 0);
        assert_eq!(e.stats.experiments, 4);
        assert_eq!(e.stats.specials, 0);
        // The reused flags mark exactly the g1 duplicates.
        assert_eq!(e.cells.iter().filter(|c| c.reused).count(), 9);
    }

    #[test]
    fn expand_skips_inexpressible_cells() {
        // g1's remote-only column (3 kernels) cannot run on one socket:
        // skipped and counted, not fatal.
        let mut params = quick();
        params.machine = crate::sim::machine::MachineConfig::xeon_6248_1s();
        let e = expand(&["g1"], &params).unwrap();
        assert_eq!(e.stats.cells_total, 18);
        assert_eq!(e.stats.cells_skipped, 3);
        assert_eq!(e.stats.cells_simulated, 15);
        assert!(e.cells.iter().all(|c| c.scenario != "remote-only"));
    }

    #[test]
    fn expand_rejects_unknown_id() {
        assert!(expand(&["f3", "zz"], &quick()).is_err());
    }

    #[test]
    fn expand_specs_matches_id_expansion() {
        let params = quick();
        let by_id = expand(&["f3", "g1"], &params).unwrap();
        let by_spec = expand_specs(spec::find_all(&["f3", "g1"]).unwrap(), &params).unwrap();
        assert_eq!(by_id.stats, by_spec.stats);
        assert_eq!(by_id.cells.len(), by_spec.cells.len());
        for (a, b) in by_id.cells.iter().zip(by_spec.cells.iter()) {
            assert_eq!(a.key, b.key);
            assert_eq!(a.reused, b.reused);
        }
    }

    fn plan_cell(kernel: &str, scenario: &str, cache: &str) -> CellPlan {
        CellPlan {
            experiment: "f3".to_string(),
            kernel: kernel.to_string(),
            scenario: scenario.to_string(),
            cache: cache.to_string(),
            key: 0xdead_beef,
            reused: false,
        }
    }

    #[test]
    fn identity_guard_accepts_matching_reuse() {
        let first = plan_cell("conv_direct_nchw", "single-thread", "cold");
        assert!(
            check_reuse_identity(&first, "conv_direct_nchw", "single-thread", "cold").is_ok()
        );
    }

    #[test]
    fn identity_guard_rejects_colliding_cells() {
        // A real FNV-1a collision cannot be constructed on demand, so the
        // guard is exercised directly: same content hash, different
        // variant identity (the failure mode a missing knob would cause).
        let first = plan_cell("conv_direct_nchw", "single-thread", "cold");
        for (kernel, scenario, cache) in [
            ("conv_direct_nchw@rb4", "single-thread", "cold"),
            ("conv_direct_nchw", "one-socket", "cold"),
            ("conv_direct_nchw", "single-thread", "warm"),
        ] {
            let err = check_reuse_identity(&first, kernel, scenario, cache).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("content-hash collision"), "{msg}");
            assert!(msg.contains("conv_direct_nchw"), "{msg}");
        }
    }

    #[test]
    fn execute_serial_matches_direct_run() {
        let params = quick();
        let direct = crate::harness::experiments::run_experiment("f6", &params).unwrap();
        let outcome = execute(&["f6"], &params, 1, false).unwrap();
        assert_eq!(outcome.results.len(), 1);
        let planned = &outcome.results[0];
        assert_eq!(planned.id, direct.id);
        assert_eq!(planned.groups.len(), direct.groups.len());
        for (a, b) in planned.groups[0]
            .measurements
            .iter()
            .zip(direct.groups[0].measurements.iter())
        {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.measured.work_flops, b.measured.work_flops);
            assert_eq!(a.measured.traffic_bytes, b.measured.traffic_bytes);
            assert_eq!(a.runtime.seconds.to_bits(), b.runtime.seconds.to_bits());
        }
    }

    #[test]
    fn job_split_never_oversubscribes() {
        // (jobs, sim_jobs, cells) → (cell_workers, sim_workers)
        for (jobs, sim_jobs, cells, want) in [
            // Deep queue: all budget to cell-level workers.
            (16, 8, 100, (16, 1)),
            // Shallow queue: spare budget flows into the cells.
            (16, 8, 2, (2, 8)),
            (16, 0, 2, (2, 8)),   // sim auto = the worker's whole share
            (16, 4, 2, (2, 4)),   // capped by sim_jobs
            (8, 8, 3, (3, 2)),    // floor(8/3) = 2 per cell
            // One cell: everything intra-cell.
            (8, 0, 1, (1, 8)),
            // sim_jobs = 1 pins the serial engine.
            (16, 1, 2, (2, 1)),
            // Degenerate budgets.
            (0, 0, 5, (1, 1)),
            (1, 8, 5, (1, 1)),
            (4, 8, 0, (1, 4)),
        ] {
            let (cell_workers, sim_workers) = job_split(jobs, sim_jobs, cells);
            assert_eq!((cell_workers, sim_workers), want, "split({jobs},{sim_jobs},{cells})");
            assert!(cell_workers * sim_workers <= jobs.max(1), "oversubscribed");
        }
    }

    #[test]
    fn job_split_cell_sim_shard_budget() {
        // The sim share of a split is spent twice over inside each
        // cell: `sim_workers` phase-A workers AND `sim_workers` phase-B
        // set shards (the sharded engine runs workers = shards = N).
        // Shards are views of one LLC, not threads, so only the
        // cell × sim product counts against the core budget — the
        // shard count rides along for free.
        for (jobs, sim_jobs, cells) in [
            (16usize, 0usize, 2usize),
            (16, 8, 2),
            (12, 0, 3),
            (8, 0, 1),
            (64, 0, 4),
            (7, 0, 2), // non-divisible budget: floor division, never round up
        ] {
            let (cell_workers, sim_workers) = job_split(jobs, sim_jobs, cells);
            let shards = sim_workers; // simulate_jobs_on: workers = shards = sim share
            assert!(cell_workers * sim_workers <= jobs.max(1), "thread oversubscription");
            assert_eq!(shards, sim_workers, "shard count must track the sim share");
            // A sim share of 1 must pin the serial engine (no sharding),
            // so budgets too tight to parallelise stay bit-for-bit on
            // the reference pipeline by construction.
            if jobs / cell_workers == 1 {
                assert_eq!(sim_workers, 1, "tight budget must select the serial engine");
            }
        }
        // Spot-check the canonical CLI shape: `--jobs 16 --sim-jobs 0`
        // over a 2-cell queue yields 2 cells × 8 workers × 8 shards.
        assert_eq!(job_split(16, 0, 2), (2, 8));
    }

    #[test]
    fn budgeted_execution_is_deterministic() {
        // The two-phase engine must be invisible in the results: a
        // budget that hands cells intra-cell workers produces the same
        // bits as the serial plan.
        let params = quick();
        let serial = execute(&["f4", "f6"], &params, 1, false).unwrap();
        // 5 unique cells under a 16-worker budget: job_split hands each
        // of the 5 cell workers 3 intra-cell phase-A workers.
        let budgeted = execute_with_budget(
            &["f4", "f6"],
            &params,
            JobBudget { jobs: 16, sim_jobs: 4 },
            false,
            None,
        )
        .unwrap();
        assert_eq!(serial.stats, budgeted.stats);
        for (a, b) in serial.cells.iter().zip(budgeted.cells.iter()) {
            assert_eq!(a.plan.key, b.plan.key);
            assert_eq!(a.measurement.measured, b.measurement.measured);
            assert_eq!(a.measurement.traffic, b.measurement.traffic);
            assert_eq!(
                a.measurement.runtime.seconds.to_bits(),
                b.measurement.runtime.seconds.to_bits(),
                "cell {} diverged under the two-phase budget",
                a.plan.key
            );
        }
    }

    #[test]
    fn parallel_execution_is_deterministic() {
        let params = quick();
        let serial = execute(&["f3", "f6"], &params, 1, false).unwrap();
        let parallel = execute(&["f3", "f6"], &params, 4, false).unwrap();
        assert_eq!(serial.stats, parallel.stats);
        assert_eq!(serial.cells.len(), parallel.cells.len());
        for (a, b) in serial.cells.iter().zip(parallel.cells.iter()) {
            assert_eq!(a.plan.key, b.plan.key);
            assert_eq!(
                a.measurement.runtime.seconds.to_bits(),
                b.measurement.runtime.seconds.to_bits(),
                "cell {} diverged between --jobs 1 and --jobs 4",
                a.plan.key
            );
        }
    }

    #[test]
    fn store_backed_execution_is_invisible_and_incremental() {
        let dir = crate::testutil::TempDir::new("plan-store");
        let store = CellStore::open(dir.path()).unwrap();
        let params = quick();
        let plain = execute(&["f6"], &params, 1, false).unwrap();
        assert!(plain.store.is_none());

        // Cold store: everything simulates, records are written back.
        let cold = execute_with_store(&["f6"], &params, 1, false, Some(&store)).unwrap();
        let u = cold.store.as_ref().unwrap();
        assert_eq!((u.hits, u.stale, u.simulated), (0, 0, 2));

        // Warm store: zero simulations, and the outcome is bit-identical
        // to the storeless run — the cache is invisible in the results.
        let warm = execute_with_store(&["f6"], &params, 1, false, Some(&store)).unwrap();
        let u = warm.store.as_ref().unwrap();
        assert_eq!((u.hits, u.stale, u.simulated), (2, 0, 0));
        assert!(u.fates.values().all(|f| *f == CellFate::Hit));
        for (a, b) in plain.cells.iter().zip(warm.cells.iter()) {
            assert_eq!(a.plan.key, b.plan.key);
            assert_eq!(a.measurement.measured, b.measurement.measured);
            assert_eq!(
                a.measurement.runtime.seconds.to_bits(),
                b.measurement.runtime.seconds.to_bits()
            );
        }
    }

    #[test]
    fn plan_edit_resimulates_only_changed_cells() {
        let dir = crate::testutil::TempDir::new("plan-edit");
        let store = CellStore::open(dir.path()).unwrap();
        let params = quick();
        execute_with_store(&["f6"], &params, 1, false, Some(&store)).unwrap();
        // Adding f3 to the plan re-simulates exactly f3's three cells;
        // f6's two come from disk.
        let edited = execute_with_store(&["f6", "f3"], &params, 2, false, Some(&store)).unwrap();
        let u = edited.store.as_ref().unwrap();
        assert_eq!((u.hits, u.stale, u.simulated), (2, 0, 3));
    }

    #[test]
    fn specials_flow_through_plan() {
        let outcome = execute(&["p1", "v1"], &quick(), 2, false).unwrap();
        assert_eq!(outcome.results.len(), 2);
        assert_eq!(outcome.stats.specials, 2);
        assert_eq!(outcome.stats.cells_total, 0);
        assert!(!outcome.results[0].tables.is_empty());
    }

    #[test]
    fn tolerant_execute_survives_impossible_special() {
        // m1 needs two sockets; tolerant mode records the skip, strict
        // mode propagates the error.
        let mut params = quick();
        params.machine = crate::sim::machine::MachineConfig::xeon_6248_1s();
        assert!(execute(&["m1"], &params, 1, false).is_err());
        let outcome = execute(&["f3", "m1"], &params, 1, true).unwrap();
        assert_eq!(outcome.results.len(), 2);
        assert!(outcome.results[1]
            .notes
            .iter()
            .any(|n| n.contains("skipped on this machine")));
        // The runnable experiment still produced real groups.
        assert!(!outcome.results[0].groups.is_empty());
    }
}
