//! Platform selection: presets or TOML-lite config files.

use anyhow::{Context, Result};

use crate::sim::machine::MachineConfig;
use crate::util::toml_lite::Doc;

/// Resolve a `--machine` argument: a preset name (`xeon_6248`,
/// `xeon_6248_1s`) or a path to a config file (see `configs/`).
pub fn resolve_machine(arg: &str) -> Result<MachineConfig> {
    match arg {
        "xeon_6248" | "xeon6248" | "paper" => Ok(MachineConfig::xeon_6248()),
        "xeon_6248_1s" => Ok(MachineConfig::xeon_6248_1s()),
        path => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("'{path}' is neither a preset (xeon_6248, xeon_6248_1s) nor a readable config file"))?;
            let doc = Doc::parse(&text).with_context(|| format!("parsing {path}"))?;
            MachineConfig::from_toml(&doc)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        assert_eq!(resolve_machine("xeon_6248").unwrap().sockets, 2);
        assert_eq!(resolve_machine("paper").unwrap().cores(), 40);
        assert_eq!(resolve_machine("xeon_6248_1s").unwrap().sockets, 1);
    }

    #[test]
    fn missing_file_errors_helpfully() {
        let err = resolve_machine("/no/such/file.toml").unwrap_err().to_string();
        assert!(err.contains("preset"), "{err}");
    }

    #[test]
    fn config_file_resolves() {
        let dir = crate::testutil::TempDir::new("cfg");
        let path = dir.join("m.toml");
        std::fs::write(&path, "name = \"small\"\nsockets = 1\ncores_per_socket = 2\n").unwrap();
        let m = resolve_machine(path.to_str().unwrap()).unwrap();
        assert_eq!(m.cores(), 2);
    }
}
