//! Persistent, content-addressed cell-result store — the plan executor's
//! memo table, spilled to disk so it survives the process.
//!
//! PR 1's executor memoizes cells *within* a sweep; this store memoizes
//! them *across* sweeps and processes: every simulated cell is written as
//! a versioned JSON record keyed by the cell's FNV content hash (machine
//! fingerprint × kernel identity × scenario × cache state — see
//! [`crate::harness::spec::Cell`]), and the next sweep over an unchanged
//! plan loads every record instead of simulating. Because the stored
//! [`KernelMeasurement`] round-trips bit-identically
//! ([`KernelMeasurement::to_json`]), a warm sweep emits byte-identical
//! reports and `run.json` manifests — the cache is invisible in the
//! output, only in the wall clock.
//!
//! ## Layout
//!
//! ```text
//! <cache-dir>/
//!   index.json            schema version, creation time, per-key hit counts
//!   cells/<key16>.json    one versioned record per cell (atomic tmp+rename)
//! ```
//!
//! ## Staleness rules
//!
//! A record is **stale** — treated as a miss, re-simulated and
//! overwritten — when any of: its file fails to parse (truncation,
//! corruption), its `schema_version` differs from
//! [`STORE_SCHEMA_VERSION`], its embedded `key` disagrees with its file
//! name, or its measurement payload fails validation. The executor
//! additionally re-checks kernel/scenario/cache identity against the
//! plan, so even an FNV collision cannot serve the wrong cell.
//!
//! Entries are written with [`write_atomic_unique`], so any number of
//! concurrent writers (threads of one `--jobs N` sweep, or independent
//! processes sharing a cache directory) can race on the same key: every
//! observable file state is some writer's complete record, and identical
//! keys hold identical content by construction.
//!
//! ```
//! use dlroofline::coordinator::store::{CellStore, Lookup};
//! let dir = std::env::temp_dir().join(format!("dlroofline-doc-store-{}", std::process::id()));
//! let store = CellStore::open(&dir).unwrap();
//! // A fresh store misses every key and holds no entries.
//! assert!(matches!(store.lookup(0xdead_beef), Lookup::Miss));
//! assert_eq!(store.stats().unwrap().entries, 0);
//! std::fs::remove_dir_all(&dir).unwrap();
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::harness::measure::KernelMeasurement;
use crate::util::fsutil::{
    read_to_string_io_with, write_atomic_unique, write_atomic_unique_with, FaultInjector,
};
use crate::util::hash::hex64;
use crate::util::json::Json;

/// Current cell-record schema version. Records written by a different
/// version are ignored (stale) and overwritten on the next simulation.
pub const STORE_SCHEMA_VERSION: u64 = 1;

/// Environment variable consulted when no `--cache-dir` flag is given.
pub const CACHE_ENV: &str = "DLROOFLINE_CACHE";

/// Outcome of probing the store for one cell key.
#[derive(Debug)]
pub enum Lookup {
    /// A valid record was found; the boxed measurement is bit-identical
    /// to the simulation that produced it.
    Hit(Box<KernelMeasurement>),
    /// No record on disk for this key.
    Miss,
    /// A record exists but cannot be used; the string says why
    /// (corruption, schema mismatch, key mismatch).
    Stale(String),
}

/// Aggregate description of a store directory (`dlroofline cache stats`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StoreStats {
    /// Valid cell records on disk.
    pub entries: usize,
    /// Records that would be ignored (unparsable or wrong version).
    pub stale: usize,
    /// Total bytes across all cell records.
    pub bytes: u64,
    /// Sum of recorded hit counts across all keys.
    pub hits_recorded: u64,
    /// Unix timestamp the index was first created (0 if unknown).
    pub created_unix: u64,
}

/// What a [`CellStore::gc`] pass did.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GcReport {
    /// Stale records removed (always pruned, regardless of the cap).
    pub removed_stale: usize,
    /// Valid records evicted to respect `max_entries` (fewest hits
    /// first, key order breaking ties).
    pub evicted: usize,
    /// Valid records kept.
    pub kept: usize,
    /// Valid records exempted from eviction because a claim file under
    /// `claims/` named them — a serve fill worker published them moments
    /// ago and its peers may still be polling for them.
    pub protected: usize,
}

/// Per-key hit counts plus index metadata, guarded for thread safety.
struct IndexState {
    created_unix: u64,
    hits: BTreeMap<String, u64>,
}

/// A disk-backed cell-result store rooted at one directory.
///
/// All methods take `&self`; the hit-count index is internally
/// synchronised, and entry writes are atomic and collision-free, so a
/// store may be shared freely across the executor's threads.
pub struct CellStore {
    root: PathBuf,
    index: Mutex<IndexState>,
    recovered: bool,
    faults: Option<Arc<FaultInjector>>,
}

impl CellStore {
    /// Open (creating if necessary) a store at `dir`. A missing,
    /// truncated, or corrupt `index.json` (including a schema-version
    /// mismatch) is **rebuilt by scanning `cells/`** rather than silently
    /// replaced with an empty index: every valid record gets an index row
    /// (hit count 0), so `gc` eviction still sees the store's true
    /// contents. Only the accumulated hit counts are lost — they merely
    /// weaken `gc` heuristics, never correctness.
    pub fn open(dir: &Path) -> Result<CellStore> {
        Self::open_with_faults(dir, None)
    }

    /// As [`CellStore::open`], with a fault injector applied to record
    /// reads and writes (the correctness surface; the advisory hit-count
    /// index stays unfaulted — it is best-effort by design). Production
    /// callers pass `None` through [`CellStore::open`]; the `faults`
    /// fuzz kind and chaos tests use this to prove that a faulted store
    /// only ever degrades to re-simulation, never to wrong results.
    pub fn open_with_faults(
        dir: &Path,
        faults: Option<Arc<FaultInjector>>,
    ) -> Result<CellStore> {
        std::fs::create_dir_all(dir.join("cells"))
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        let index_path = dir.join("index.json");
        let index = std::fs::read_to_string(&index_path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|doc| Self::index_from_json(&doc));
        let recovered = index.is_none();
        let store = CellStore {
            root: dir.to_path_buf(),
            index: Mutex::new(index.unwrap_or_else(|| IndexState {
                created_unix: now_unix(),
                hits: BTreeMap::new(),
            })),
            recovered,
            faults,
        };
        if recovered {
            // Best-effort persistence: a read-only pre-seeded cache still
            // serves hits off the rebuilt in-memory index.
            let _ = store.rebuild_index();
        }
        Ok(store)
    }

    /// True when `open` found no usable `index.json` and rebuilt the
    /// index from the `cells/` scan (also true for a brand-new dir).
    pub fn recovered_index(&self) -> bool {
        self.recovered
    }

    /// Re-derive the index from the record files: one row (hit count 0)
    /// per valid record, `created_unix` backdated to the oldest record's
    /// mtime so eviction-age heuristics stay sane. Existing in-memory
    /// rows are kept (rebuild only adds), then the result is persisted
    /// verbatim — the on-disk index is the thing being repaired, so no
    /// disk merge.
    fn rebuild_index(&self) -> Result<()> {
        let scan = self.scan()?;
        let mut created = now_unix();
        {
            let mut index = self.index.lock().unwrap();
            for (stem, path, _, valid) in &scan {
                if !valid {
                    continue;
                }
                index.hits.entry(stem.clone()).or_insert(0);
                if let Some(mtime) = std::fs::metadata(path)
                    .and_then(|m| m.modified())
                    .ok()
                    .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
                {
                    created = created.min(mtime.as_secs());
                }
            }
            index.created_unix = index.created_unix.min(created);
        }
        self.save_index_replacing()
    }

    /// Resolve the cache directory from an explicit flag value, falling
    /// back to the [`CACHE_ENV`] environment variable. `None` means
    /// caching is disabled.
    pub fn resolve_dir(flag: Option<&str>) -> Option<PathBuf> {
        match flag {
            Some(dir) if !dir.is_empty() => Some(PathBuf::from(dir)),
            _ => std::env::var(CACHE_ENV).ok().filter(|s| !s.is_empty()).map(PathBuf::from),
        }
    }

    /// The directory this store is rooted at.
    pub fn root(&self) -> &Path {
        &self.root
    }

    fn entry_path(&self, key: u64) -> PathBuf {
        self.root.join("cells").join(format!("{}.json", hex64(key)))
    }

    /// The on-disk path of `key`'s record file (which may not exist).
    /// The artifact packer reads record files verbatim through this, so
    /// packed checksums match the bytes the store would serve.
    pub fn record_path(&self, key: u64) -> PathBuf {
        self.entry_path(key)
    }

    /// Install a record *verbatim* from `text` — how `unpack --seed-cache`
    /// transplants packed cells into a local store. The text must parse
    /// as a valid record for `key` (same rules as [`CellStore::lookup`]);
    /// writing byte-for-byte what was packed keeps the seeded store's
    /// records checksum-identical to the source host's.
    pub fn seed_record(&self, key: u64, text: &str) -> Result<()> {
        let doc = Json::parse(text)
            .with_context(|| format!("seed record for {} is not JSON", hex64(key)))?;
        Self::record_from_json(&doc, key)
            .with_context(|| format!("seed record for {} is not servable", hex64(key)))?;
        write_atomic_unique_with(&self.entry_path(key), text, self.faults.as_deref())
    }

    /// Probe the store for `key`. Never fails: every unusable state maps
    /// to [`Lookup::Miss`] or [`Lookup::Stale`] so the caller can always
    /// fall back to simulation.
    pub fn lookup(&self, key: u64) -> Lookup {
        let path = self.entry_path(key);
        let text = match read_to_string_io_with(&path, self.faults.as_deref()) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Lookup::Miss,
            Err(e) => return Lookup::Stale(format!("unreadable: {e}")),
        };
        let doc = match Json::parse(&text) {
            Ok(d) => d,
            Err(e) => return Lookup::Stale(format!("corrupt record: {e}")),
        };
        match Self::record_from_json(&doc, key) {
            Ok(m) => Lookup::Hit(Box::new(m)),
            Err(e) => Lookup::Stale(format!("{e:#}")),
        }
    }

    fn record_from_json(doc: &Json, key: u64) -> Result<KernelMeasurement> {
        let version = doc.expect("schema_version")?.as_usize()? as u64;
        if version != STORE_SCHEMA_VERSION {
            anyhow::bail!(
                "record schema version {version} (this build writes {STORE_SCHEMA_VERSION})"
            );
        }
        let recorded = doc.expect("key")?.as_str()?;
        if recorded != hex64(key) {
            anyhow::bail!("record key {recorded} does not match file name {}", hex64(key));
        }
        KernelMeasurement::from_json(doc.expect("measurement")?)
    }

    /// Write `measurement` as the record for `key` (atomic; safe against
    /// concurrent writers of the same key).
    pub fn insert(&self, key: u64, measurement: &KernelMeasurement) -> Result<()> {
        let doc = Json::obj(vec![
            ("schema_version", Json::num(STORE_SCHEMA_VERSION as f64)),
            ("key", Json::str(hex64(key))),
            ("measurement", measurement.to_json()),
        ]);
        write_atomic_unique_with(
            &self.entry_path(key),
            &doc.to_string_pretty(),
            self.faults.as_deref(),
        )
    }

    /// Record one served hit for each key (in memory; call
    /// [`CellStore::save_index`] to persist).
    pub fn mark_hits(&self, keys: &[u64]) {
        let mut index = self.index.lock().unwrap();
        for &key in keys {
            *index.hits.entry(hex64(key)).or_insert(0) += 1;
        }
    }

    /// Persist the hit-count index, merging with whatever is on disk
    /// (another process may have saved since we loaded): per key, the
    /// larger count wins. Best-effort by design — hit counts only feed
    /// `gc` eviction order.
    pub fn save_index(&self) -> Result<()> {
        self.save_index_inner(true)
    }

    /// Persist the index *without* the disk merge — what `clear`/`gc`
    /// need, since merging would resurrect the very rows they purged.
    fn save_index_replacing(&self) -> Result<()> {
        self.save_index_inner(false)
    }

    fn save_index_inner(&self, merge: bool) -> Result<()> {
        let index_path = self.root.join("index.json");
        let mut state = self.index.lock().unwrap();
        if merge {
            if let Some(disk) = std::fs::read_to_string(&index_path)
                .ok()
                .and_then(|text| Json::parse(&text).ok())
                .and_then(|doc| Self::index_from_json(&doc))
            {
                for (key, count) in disk.hits {
                    let mine = state.hits.entry(key).or_insert(0);
                    *mine = (*mine).max(count);
                }
                if disk.created_unix != 0 {
                    state.created_unix = state.created_unix.min(disk.created_unix);
                }
            }
        }
        let doc = Json::obj(vec![
            ("schema_version", Json::num(STORE_SCHEMA_VERSION as f64)),
            ("created_unix", Json::num(state.created_unix as f64)),
            (
                "hits",
                Json::Obj(
                    state
                        .hits
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
        ]);
        write_atomic_unique(&index_path, &doc.to_string_pretty())
    }

    fn index_from_json(doc: &Json) -> Option<IndexState> {
        let version = doc.get("schema_version")?.as_usize().ok()? as u64;
        if version != STORE_SCHEMA_VERSION {
            return None;
        }
        let mut hits = BTreeMap::new();
        for (k, v) in doc.get("hits")?.as_obj().ok()? {
            hits.insert(k.clone(), v.as_usize().ok()? as u64);
        }
        Some(IndexState {
            created_unix: doc.get("created_unix")?.as_usize().ok()? as u64,
            hits,
        })
    }

    /// Every record file currently in the store, as (key hex, path,
    /// bytes, valid) — `valid` applies the same rules as
    /// [`CellStore::lookup`].
    fn scan(&self) -> Result<Vec<(String, PathBuf, u64, bool)>> {
        let cells = self.root.join("cells");
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&cells)
            .with_context(|| format!("reading cache dir {}", cells.display()))?
        {
            let entry = entry?;
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().to_string();
            let Some(stem) = name.strip_suffix(".json") else {
                continue; // in-flight tmp files and strangers are not records
            };
            let bytes = entry.metadata().map(|m| m.len()).unwrap_or(0);
            let valid = u64::from_str_radix(stem, 16)
                .ok()
                .map(|key| matches!(self.lookup(key), Lookup::Hit(_)))
                .unwrap_or(false);
            out.push((stem.to_string(), path, bytes, valid));
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(out)
    }

    /// Summarise the store (`dlroofline cache stats`).
    pub fn stats(&self) -> Result<StoreStats> {
        let scan = self.scan()?;
        let index = self.index.lock().unwrap();
        Ok(StoreStats {
            entries: scan.iter().filter(|e| e.3).count(),
            stale: scan.iter().filter(|e| !e.3).count(),
            bytes: scan.iter().map(|e| e.2).sum(),
            hits_recorded: index.hits.values().sum(),
            created_unix: index.created_unix,
        })
    }

    /// Remove every record and reset the index. Returns how many record
    /// files were deleted.
    pub fn clear(&self) -> Result<usize> {
        let scan = self.scan()?;
        let removed = scan.len();
        for (_, path, _, _) in scan {
            std::fs::remove_file(&path)
                .with_context(|| format!("removing {}", path.display()))?;
        }
        {
            let mut index = self.index.lock().unwrap();
            index.hits.clear();
        }
        self.save_index_replacing()?;
        Ok(removed)
    }

    /// Keys currently named by claim files under `claims/` — cells an
    /// active serve fill is publishing or polling for. Missing dir (no
    /// daemon ever shared this cache) means no claims.
    fn claimed_keys(&self) -> std::collections::BTreeSet<String> {
        let mut out = std::collections::BTreeSet::new();
        let Ok(entries) = std::fs::read_dir(self.root.join("claims")) else {
            return out;
        };
        for entry in entries.flatten() {
            let name = entry.file_name().to_string_lossy().to_string();
            if let Some(stem) = name.strip_suffix(".claim") {
                out.insert(stem.to_string());
            }
        }
        out
    }

    /// Prune the store: stale records always go; then, if more than
    /// `max_entries` valid records remain, evict the least-hit ones
    /// (ties broken by key order, so a gc pass is deterministic for a
    /// given index). Records named by a live claim file are never
    /// evicted — a gc racing an active serve fill must not snatch a
    /// freshly published record out from under the workers polling the
    /// store for it.
    pub fn gc(&self, max_entries: usize) -> Result<GcReport> {
        let scan = self.scan()?;
        let claimed = self.claimed_keys();
        let mut report = GcReport::default();
        let mut protected: Vec<String> = Vec::new();
        let mut valid: Vec<(String, PathBuf)> = Vec::new();
        for (key, path, _, ok) in scan {
            if !ok {
                std::fs::remove_file(&path)
                    .with_context(|| format!("removing stale {}", path.display()))?;
                report.removed_stale += 1;
            } else if claimed.contains(&key) {
                protected.push(key);
            } else {
                valid.push((key, path));
            }
        }
        report.protected = protected.len();
        let mut index = self.index.lock().unwrap();
        // Fewest hits first; the scan's key order breaks ties.
        valid.sort_by_key(|(key, _)| index.hits.get(key).copied().unwrap_or(0));
        let target = max_entries.saturating_sub(protected.len());
        let excess = valid.len().saturating_sub(target);
        for (key, path) in valid.drain(..excess) {
            std::fs::remove_file(&path)
                .with_context(|| format!("evicting {}", path.display()))?;
            index.hits.remove(&key);
            report.evicted += 1;
        }
        report.kept = valid.len() + protected.len();
        // Drop index rows for records that no longer exist (stale ones
        // removed above, or entries deleted out-of-band).
        let live: std::collections::BTreeSet<String> = valid
            .into_iter()
            .map(|(k, _)| k)
            .chain(protected)
            .collect();
        index.hits.retain(|k, _| live.contains(k));
        drop(index);
        self.save_index_replacing()?;
        Ok(report)
    }
}

fn now_unix() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::experiments::ExperimentParams;
    use crate::harness::spec;
    use crate::testutil::TempDir;

    fn quick() -> ExperimentParams {
        ExperimentParams { batch: Some(1), ..Default::default() }
    }

    /// One real simulated cell (f6 cold) and its key.
    fn one_cell() -> (u64, KernelMeasurement) {
        let params = quick();
        let cells = spec::find("f6").unwrap().cells();
        let cell = &cells[0];
        (cell.key(&params), cell.simulate(&params).unwrap())
    }

    #[test]
    fn insert_then_lookup_hits() {
        let dir = TempDir::new("store-hit");
        let store = CellStore::open(dir.path()).unwrap();
        let (key, meas) = one_cell();
        assert!(matches!(store.lookup(key), Lookup::Miss));
        store.insert(key, &meas).unwrap();
        match store.lookup(key) {
            Lookup::Hit(back) => {
                assert_eq!(back.kernel, meas.kernel);
                assert_eq!(back.measured, meas.measured);
                assert_eq!(back.runtime.seconds.to_bits(), meas.runtime.seconds.to_bits());
            }
            other => panic!("expected hit, got {other:?}"),
        }
    }

    #[test]
    fn truncated_record_is_stale() {
        let dir = TempDir::new("store-trunc");
        let store = CellStore::open(dir.path()).unwrap();
        let (key, meas) = one_cell();
        store.insert(key, &meas).unwrap();
        let path = store.entry_path(key);
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &body[..body.len() / 2]).unwrap();
        assert!(matches!(store.lookup(key), Lookup::Stale(_)));
    }

    #[test]
    fn version_mismatch_is_stale() {
        let dir = TempDir::new("store-ver");
        let store = CellStore::open(dir.path()).unwrap();
        let (key, meas) = one_cell();
        store.insert(key, &meas).unwrap();
        let path = store.entry_path(key);
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        if let Json::Obj(mut map) = doc {
            map.insert("schema_version".into(), Json::num(99.0));
            std::fs::write(&path, Json::Obj(map).to_string_pretty()).unwrap();
        }
        match store.lookup(key) {
            Lookup::Stale(reason) => assert!(reason.contains("schema version 99"), "{reason}"),
            other => panic!("expected stale, got {other:?}"),
        }
    }

    #[test]
    fn key_mismatch_is_stale() {
        // A record copied to the wrong file name must not be served.
        let dir = TempDir::new("store-keymix");
        let store = CellStore::open(dir.path()).unwrap();
        let (key, meas) = one_cell();
        store.insert(key, &meas).unwrap();
        std::fs::copy(store.entry_path(key), store.entry_path(key ^ 1)).unwrap();
        assert!(matches!(store.lookup(key ^ 1), Lookup::Stale(_)));
    }

    #[test]
    fn stats_clear_and_gc() {
        let dir = TempDir::new("store-gc");
        let store = CellStore::open(dir.path()).unwrap();
        let (key, meas) = one_cell();
        for i in 0..4u64 {
            store.insert(key.wrapping_add(i), &meas).unwrap();
        }
        // Corrupt two of the four records by truncation → stale.
        for i in 1..3u64 {
            let path = store.entry_path(key.wrapping_add(i));
            let body = std::fs::read_to_string(&path).unwrap();
            std::fs::write(&path, &body[..20]).unwrap();
        }
        let s = store.stats().unwrap();
        assert_eq!(s.entries, 2);
        assert_eq!(s.stale, 2);
        assert!(s.bytes > 0);

        // gc removes the stale records and honours the cap.
        let report = store.gc(10).unwrap();
        assert_eq!(report.removed_stale, 2);
        assert_eq!(report.kept, 2);
        assert_eq!(report.evicted, 0);
        assert_eq!(store.stats().unwrap().stale, 0);

        assert_eq!(store.clear().unwrap(), 2);
        let cleared = store.stats().unwrap();
        assert_eq!(cleared.entries, 0);
        assert_eq!(cleared.stale, 0);
        assert_eq!(cleared.hits_recorded, 0);
    }

    #[test]
    fn gc_evicts_fewest_hits_first() {
        let dir = TempDir::new("store-evict");
        let store = CellStore::open(dir.path()).unwrap();
        let params = quick();
        let cells = spec::find("f6").unwrap().cells();
        let keys: Vec<u64> = cells.iter().map(|c| c.key(&params)).collect();
        for (cell, &key) in cells.iter().zip(&keys) {
            store.insert(key, &cell.simulate(&params).unwrap()).unwrap();
        }
        store.mark_hits(&[keys[1], keys[1], keys[0]]);
        let report = store.gc(1).unwrap();
        assert_eq!(report.evicted, 1);
        assert!(matches!(store.lookup(keys[1]), Lookup::Hit(_)), "most-hit key must survive");
        assert!(matches!(store.lookup(keys[0]), Lookup::Miss));
    }

    #[test]
    fn index_survives_reopen_and_merges() {
        let dir = TempDir::new("store-index");
        let (key, _) = one_cell();
        {
            let store = CellStore::open(dir.path()).unwrap();
            store.mark_hits(&[key, key]);
            store.save_index().unwrap();
        }
        let store = CellStore::open(dir.path()).unwrap();
        assert_eq!(store.stats().unwrap().hits_recorded, 2);
        // Merging keeps the larger per-key count.
        store.mark_hits(&[key]);
        store.save_index().unwrap();
        let again = CellStore::open(dir.path()).unwrap();
        assert_eq!(again.stats().unwrap().hits_recorded, 3);
    }

    #[test]
    fn clear_and_gc_purge_the_persisted_index() {
        // clear/gc must not let the disk-merge resurrect purged rows:
        // a reopened store sees the purge, not ghost hit counts.
        let dir = TempDir::new("store-purge");
        let (key, meas) = one_cell();
        {
            let store = CellStore::open(dir.path()).unwrap();
            store.insert(key, &meas).unwrap();
            store.mark_hits(&[key, key, key]);
            store.save_index().unwrap();
            assert_eq!(store.clear().unwrap(), 1);
            assert_eq!(store.stats().unwrap().hits_recorded, 0);
        }
        let reopened = CellStore::open(dir.path()).unwrap();
        assert_eq!(
            reopened.stats().unwrap().hits_recorded,
            0,
            "cleared hit counts must stay cleared across reopen"
        );

        // gc: evicted keys' counts must not come back either.
        reopened.insert(key, &meas).unwrap();
        reopened.insert(key ^ 1, &meas).unwrap();
        // Truncate the second record → stale.
        let victim = reopened.entry_path(key ^ 1);
        let body = std::fs::read_to_string(&victim).unwrap();
        std::fs::write(&victim, &body[..16]).unwrap();
        reopened.mark_hits(&[key]);
        reopened.save_index().unwrap();
        let report = reopened.gc(0).unwrap();
        assert_eq!(report.removed_stale, 1);
        assert_eq!(report.evicted, 1);
        let again = CellStore::open(dir.path()).unwrap();
        assert_eq!(again.stats().unwrap().hits_recorded, 0, "gc purge must persist");
    }

    #[test]
    fn concurrent_inserts_never_clobber() {
        // The robustness property ISSUE 3 pins: concurrent writers of the
        // same and different keys leave only complete, valid records.
        let dir = TempDir::new("store-conc");
        let store = CellStore::open(dir.path()).unwrap();
        let (key, meas) = one_cell();
        std::thread::scope(|scope| {
            for i in 0..8u64 {
                let store = &store;
                let meas = &meas;
                scope.spawn(move || {
                    store.insert(key, meas).unwrap(); // everyone races this key
                    store.insert(key.wrapping_add(1000 + i), meas).unwrap();
                });
            }
        });
        assert!(matches!(store.lookup(key), Lookup::Hit(_)));
        // Every record parses as complete JSON (stale-by-key-mismatch is
        // fine for the shifted keys; torn files would be parse errors).
        for (stem, path, _, _) in store.scan().unwrap() {
            let text = std::fs::read_to_string(&path).unwrap();
            Json::parse(&text).unwrap_or_else(|e| panic!("torn record {stem}: {e}"));
        }
        assert!(store.entry_path(key).exists());
    }

    #[test]
    fn gc_never_evicts_a_claimed_record() {
        // A claim file names a cell an active serve fill just published;
        // gc must not snatch it out from under the workers polling for
        // it, no matter how tight the cap.
        let dir = TempDir::new("store-gc-claims");
        let store = CellStore::open(dir.path()).unwrap();
        let params = quick();
        let cells = spec::find("f6").unwrap().cells();
        let keys: Vec<u64> = cells.iter().map(|c| c.key(&params)).collect();
        for (cell, &key) in cells.iter().zip(&keys) {
            store.insert(key, &cell.simulate(&params).unwrap()).unwrap();
        }
        let claims = crate::serve::claims::ClaimSet::new(
            store.root(),
            std::time::Duration::from_secs(600),
        );
        assert_eq!(
            claims.claim(keys[0]).unwrap(),
            crate::serve::claims::ClaimOutcome::Won
        );

        let report = store.gc(0).unwrap();
        assert_eq!(report.protected, 1);
        assert_eq!(report.evicted, keys.len() - 1);
        assert!(
            matches!(store.lookup(keys[0]), Lookup::Hit(_)),
            "claimed record must survive gc"
        );

        // Once the claim is released the record is fair game again.
        claims.release(keys[0]);
        let report = store.gc(0).unwrap();
        assert_eq!(report.protected, 0);
        assert_eq!(report.evicted, 1);
        assert!(matches!(store.lookup(keys[0]), Lookup::Miss));
    }

    #[test]
    fn faulted_store_degrades_to_stale_or_miss_never_garbage() {
        use crate::util::fsutil::{FaultInjector, FaultPlan, WritePlan};

        // A store whose first record write is torn: the lookup must see
        // the damage (stale), and the retry must heal it bit-identically.
        let dir = TempDir::new("store-faulted");
        let inj = std::sync::Arc::new(FaultInjector::new(FaultPlan {
            write: Some(WritePlan::Torn { at: 0 }),
            read: None,
        }));
        let store = CellStore::open_with_faults(dir.path(), Some(inj.clone())).unwrap();
        let (key, meas) = one_cell();
        store.insert(key, &meas).unwrap(); // torn — publishes a prefix
        assert!(matches!(store.lookup(key), Lookup::Stale(_)));
        assert_eq!(inj.injected(), 1);
        store.insert(key, &meas).unwrap(); // plan exhausted — clean write
        match store.lookup(key) {
            Lookup::Hit(back) => {
                assert_eq!(back.to_json().to_string_pretty(), meas.to_json().to_string_pretty());
            }
            other => panic!("expected healed hit, got {other:?}"),
        }
    }

    #[test]
    fn resolve_dir_prefers_flag() {
        assert_eq!(
            CellStore::resolve_dir(Some("/x/y")),
            Some(PathBuf::from("/x/y"))
        );
        // Empty flag value falls through to the environment (not a panic
        // and not an empty path).
        let from_env = CellStore::resolve_dir(Some(""));
        assert_eq!(from_env, CellStore::resolve_dir(None));
    }
}
