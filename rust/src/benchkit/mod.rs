//! A small criterion-style benchmarking harness.
//!
//! `criterion` is unavailable offline, so benches under `benches/` use this
//! instead (`harness = false` in `Cargo.toml`). Features: wallclock warmup,
//! adaptive iteration-count selection targeting a measurement window,
//! outlier rejection, throughput units, and aligned table / CSV output.
//!
//! The statistical protocol intentionally mirrors the paper's §2.5:
//! repeated executions, averaged, with optional warm-up ("warm caches")
//! pre-runs.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::human::{fmt_seconds, fmt_si, pad_left, pad_right};
use crate::util::json::Json;
use crate::util::stats::{reject_outliers, Summary};

/// Schema version of the `BENCH_<group>.json` documents emitted by
/// [`Bencher::write_json`].
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// Identity of the benching host, embedded in emitted bench JSON so
/// the perf trajectory across PRs compares like with like (numbers from
/// different machines are different series).
pub fn host_fingerprint() -> Json {
    let cpus = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    Json::obj(vec![
        ("os", Json::str(std::env::consts::OS)),
        ("arch", Json::str(std::env::consts::ARCH)),
        ("cpus", Json::num(cpus as f64)),
    ])
}

/// Benchmark configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Warmup wallclock budget before measuring.
    pub warmup: Duration,
    /// Target measurement wallclock budget.
    pub measure: Duration,
    /// Min/max sample count.
    pub min_samples: usize,
    /// Maximum sample count.
    pub max_samples: usize,
    /// Std-dev multiple for outlier rejection (0 disables).
    pub outlier_k: f64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_samples: 10,
            max_samples: 200,
            outlier_k: 3.0,
        }
    }
}

impl Config {
    /// A faster profile for CI / `cargo test`.
    pub fn quick() -> Self {
        Config {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            min_samples: 5,
            max_samples: 50,
            outlier_k: 3.0,
        }
    }

    /// Honour `DLROOFLINE_BENCH_QUICK=1` for fast smoke runs.
    pub fn from_env() -> Self {
        if std::env::var("DLROOFLINE_BENCH_QUICK").as_deref() == Ok("1") {
            Config::quick()
        } else {
            Config::default()
        }
    }
}

/// Units in which to express throughput for a benchmark.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Throughput {
    /// No throughput — report time only.
    None,
    /// Bytes processed per iteration → B/s.
    Bytes(f64),
    /// FLOPs per iteration → FLOP/s.
    Flops(f64),
    /// Abstract elements per iteration → elem/s.
    Elements(f64),
}

/// One finished measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Bench name, unique within its group.
    pub name: String,
    /// Per-iteration time statistics, seconds.
    pub time: Summary,
    /// Work per iteration, for rate derivation.
    pub throughput: Throughput,
}

impl Measurement {
    /// Mean throughput in the unit implied by `throughput`, if any.
    pub fn rate(&self) -> Option<f64> {
        match self.throughput {
            Throughput::None => None,
            Throughput::Bytes(b) => Some(b / self.time.mean),
            Throughput::Flops(f) => Some(f / self.time.mean),
            Throughput::Elements(e) => Some(e / self.time.mean),
        }
    }

    fn rate_str(&self) -> String {
        match (self.rate(), self.throughput) {
            (Some(r), Throughput::Bytes(_)) => fmt_si(r, "B/s"),
            (Some(r), Throughput::Flops(_)) => fmt_si(r, "FLOP/s"),
            (Some(r), Throughput::Elements(_)) => fmt_si(r, "elem/s"),
            _ => "-".to_string(),
        }
    }
}

/// The bench runner: collects measurements and renders a report.
pub struct Bencher {
    config: Config,
    results: Vec<Measurement>,
    group: String,
}

impl Bencher {
    /// Bencher for `group` with [`Config::from_env`] settings.
    pub fn new(group: &str) -> Self {
        Bencher {
            config: Config::from_env(),
            results: Vec::new(),
            group: group.to_string(),
        }
    }

    /// Bencher for `group` with explicit settings.
    pub fn with_config(group: &str, config: Config) -> Self {
        Bencher { config, results: Vec::new(), group: group.to_string() }
    }

    /// Benchmark `f`, which performs ONE logical iteration and returns a
    /// value kept opaque to the optimizer via `std::hint::black_box`.
    pub fn bench<T>(&mut self, name: &str, throughput: Throughput, mut f: impl FnMut() -> T) -> &Measurement {
        // Warmup + calibration: find how long one iteration takes.
        let warm_start = Instant::now();
        let mut calib_iters = 0u64;
        while warm_start.elapsed() < self.config.warmup || calib_iters == 0 {
            std::hint::black_box(f());
            calib_iters += 1;
            if calib_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / calib_iters as f64;

        // Choose sample count to fit the measurement budget.
        let budget = self.config.measure.as_secs_f64();
        let samples = ((budget / per_iter.max(1e-9)) as usize)
            .clamp(self.config.min_samples, self.config.max_samples);

        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let kept = if self.config.outlier_k > 0.0 {
            reject_outliers(&times, self.config.outlier_k)
        } else {
            times
        };
        let m = Measurement {
            name: name.to_string(),
            time: Summary::of(&kept),
            throughput,
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record a pre-computed sample set (used when the "benchmark" is a
    /// simulation that reports model time rather than wallclock).
    pub fn record(&mut self, name: &str, throughput: Throughput, seconds: &[f64]) -> &Measurement {
        let m = Measurement {
            name: name.to_string(),
            time: Summary::of(seconds),
            throughput,
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Every measurement recorded so far, in bench order.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Render an aligned text table.
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.group));
        out.push_str(&format!(
            "{} {} {} {} {}\n",
            pad_right("benchmark", 44),
            pad_left("mean", 12),
            pad_left("p05", 12),
            pad_left("p95", 12),
            pad_left("throughput", 16),
        ));
        for m in &self.results {
            out.push_str(&format!(
                "{} {} {} {} {}\n",
                pad_right(&m.name, 44),
                pad_left(&fmt_seconds(m.time.mean), 12),
                pad_left(&fmt_seconds(m.time.p05), 12),
                pad_left(&fmt_seconds(m.time.p95), 12),
                pad_left(&m.rate_str(), 16),
            ));
        }
        out
    }

    /// The group's results as a machine-readable JSON document:
    /// `{schema_version, group, quick, host, benches: {name → {mean_s,
    /// stddev_s, p05_s, p95_s, samples, unit, rate}}}`. `rate` is the
    /// mean throughput in `unit` (elements, bytes or FLOPs per second),
    /// or `null` for time-only benches; `quick` records whether the run
    /// used the shortened `DLROOFLINE_BENCH_QUICK` profile, so smoke
    /// numbers aren't mistaken for trajectory points.
    pub fn to_json(&self) -> Json {
        let benches = self
            .results
            .iter()
            .map(|m| {
                let (unit, rate) = match (m.rate(), m.throughput) {
                    (Some(r), Throughput::Bytes(_)) => ("B/s", Json::num(r)),
                    (Some(r), Throughput::Flops(_)) => ("FLOP/s", Json::num(r)),
                    (Some(r), Throughput::Elements(_)) => ("elem/s", Json::num(r)),
                    _ => ("", Json::Null),
                };
                let fields = Json::obj(vec![
                    ("mean_s", Json::num(m.time.mean)),
                    ("stddev_s", Json::num(m.time.stddev)),
                    ("p05_s", Json::num(m.time.p05)),
                    ("p95_s", Json::num(m.time.p95)),
                    ("samples", Json::num(m.time.n as f64)),
                    ("unit", Json::str(unit)),
                    ("rate", rate),
                ]);
                (m.name.clone(), fields)
            })
            .collect();
        let quick = std::env::var("DLROOFLINE_BENCH_QUICK").as_deref() == Ok("1");
        Json::obj(vec![
            ("schema_version", Json::num(BENCH_SCHEMA_VERSION as f64)),
            ("group", Json::str(self.group.as_str())),
            ("quick", Json::Bool(quick)),
            ("host", host_fingerprint()),
            ("benches", Json::Obj(benches)),
        ])
    }

    /// Write [`Bencher::to_json`] to `BENCH_<group>.json` under `dir`
    /// (atomically), returning the path.
    pub fn write_json(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.group));
        crate::util::fsutil::write_atomic(&path, &self.to_json().to_string_pretty())?;
        Ok(path)
    }

    /// Emit the bench JSON where the perf trajectory is tracked: the
    /// `DLROOFLINE_BENCH_OUT` directory if set, else the current
    /// directory (the repo root under `cargo bench`).
    pub fn emit_json(&self) -> anyhow::Result<PathBuf> {
        let dir = std::env::var("DLROOFLINE_BENCH_OUT")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("."));
        self.write_json(&dir)
    }

    /// Render CSV (for external tracking/plotting tooling).
    pub fn csv(&self) -> String {
        let mut out = String::from("group,benchmark,mean_s,stddev_s,p05_s,p95_s,samples,rate\n");
        for m in &self.results {
            out.push_str(&format!(
                "{},{},{:.9},{:.9},{:.9},{:.9},{},{}\n",
                self.group,
                m.name,
                m.time.mean,
                m.time.stddev,
                m.time.p05,
                m.time.p95,
                m.time.n,
                m.rate().map(|r| format!("{r:.3}")).unwrap_or_default(),
            ));
        }
        out
    }

    /// Print the table to stdout (benches call this at the end).
    pub fn finish(&self) {
        println!("{}", self.table());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_cheap_function() {
        let mut b = Bencher::with_config("t", Config::quick());
        let m = b.bench("noop-ish", Throughput::Elements(100.0), || {
            (0..100u64).map(std::hint::black_box).sum::<u64>()
        });
        assert!(m.time.mean > 0.0);
        assert!(m.rate().unwrap() > 0.0);
        assert!(m.time.n >= 5);
    }

    #[test]
    fn record_precomputed() {
        let mut b = Bencher::new("t");
        let m = b.record("sim", Throughput::Flops(1e9), &[0.5, 0.5, 0.5]);
        assert_eq!(m.time.mean, 0.5);
        assert_eq!(m.rate().unwrap(), 2e9);
    }

    #[test]
    fn table_contains_rows() {
        let mut b = Bencher::new("grp");
        b.record("a", Throughput::None, &[1.0]);
        b.record("b", Throughput::Bytes(1e6), &[0.001]);
        let t = b.table();
        assert!(t.contains("grp"));
        assert!(t.contains("a"));
        assert!(t.contains("B/s"));
        let csv = b.csv();
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn throughput_units() {
        let mut b = Bencher::new("u");
        let m = b.record("f", Throughput::Flops(2e9), &[1.0]);
        assert!(m.rate_str().contains("GFLOP/s"), "{}", m.rate_str());
    }

    #[test]
    fn json_document_shape() {
        let mut b = Bencher::new("grp");
        b.record("probe", Throughput::Elements(1e6), &[0.5, 0.5]);
        b.record("timed", Throughput::None, &[1.0]);
        let doc = b.to_json();
        assert_eq!(doc.get("group").and_then(|g| g.as_str().ok()), Some("grp"));
        assert!(doc.get("host").and_then(|h| h.get("arch")).is_some());
        let benches = doc.get("benches").expect("benches object");
        let probe = benches.get("probe").expect("probe entry");
        assert_eq!(probe.get("unit").and_then(|u| u.as_str().ok()), Some("elem/s"));
        assert_eq!(probe.get("rate").and_then(|r| r.as_f64().ok()), Some(2e6));
        let timed = benches.get("timed").expect("timed entry");
        assert_eq!(timed.get("rate"), Some(&Json::Null));
        // The document round-trips through the parser.
        let text = doc.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn write_json_lands_as_bench_group_file() {
        let dir = crate::testutil::TempDir::new("benchkit-json");
        let mut b = Bencher::new("sim_hotpath");
        b.record("stream", Throughput::Elements(1e6), &[0.25]);
        let path = b.write_json(dir.path()).unwrap();
        assert!(path.ends_with("BENCH_sim_hotpath.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert!(doc.get("benches").and_then(|bs| bs.get("stream")).is_some());
    }
}
