//! Compare two platforms with the same kernels — the paper's §1 fourth
//! use of rooflines ("compare performance of computing platforms").
//!
//! ```sh
//! cargo run --release --example platform_compare
//! ```

use dlroofline::harness::{measure_kernel, CacheState, ScenarioSpec};
use dlroofline::kernels::conv_direct::ConvDirectBlocked;
use dlroofline::kernels::gelu::{EltwiseShape, GeluNchw};
use dlroofline::kernels::ConvShape;
use dlroofline::roofline::model::RooflineModel;
use dlroofline::sim::machine::{Machine, MachineConfig};
use dlroofline::util::human::{fmt_flops, fmt_pct, fmt_seconds};

fn main() -> anyhow::Result<()> {
    // The paper's server CPU vs a small AVX-512 workstation (1 FMA port,
    // 2 DDR channels) — same kernels, very different rooflines.
    let server = MachineConfig::xeon_6248();
    let mut workstation = MachineConfig::xeon_6248_1s();
    workstation.name = "workstation_8c".into();
    workstation.cores_per_socket = 8;
    workstation.core.fma_ports = 1.0;
    workstation.core.freq_avx512 = 2.8e9;
    workstation.dram.channels = 2;

    let conv = ConvDirectBlocked::new(ConvShape::paper_conv(4));
    let gelu = GeluNchw::new(EltwiseShape::favourable(16));

    println!(
        "{:<16} {:<22} {:>12} {:>10} {:>10} {:>8}",
        "platform", "kernel", "runtime", "perf", "util π", "bound"
    );
    for config in [&server, &workstation] {
        let roofline = RooflineModel::for_machine(
            config,
            config.cores_per_socket,
            1,
            "one-socket",
        );
        for kernel in [&conv as &dyn dlroofline::kernels::KernelModel, &gelu] {
            let mut machine = Machine::new(config.clone());
            let m = measure_kernel(
                &mut machine,
                kernel,
                &ScenarioSpec::one_socket(),
                CacheState::Cold,
            )?;
            let p = m.point();
            println!(
                "{:<16} {:<22} {:>12} {:>10} {:>10} {:>8}",
                config.name,
                m.kernel,
                fmt_seconds(p.runtime),
                fmt_flops(p.perf()),
                fmt_pct(p.utilization(&roofline)),
                format!("{:?}", m.runtime.bound),
            );
        }
    }
    println!(
        "\nThe compute-bound conv keeps its utilisation on the smaller part \
         (the ceiling moved down with it); the memory-bound GELU is at the \
         mercy of the channel count — exactly what a roofline predicts."
    );
    Ok(())
}
