//! The end-to-end paper reproduction driver (EXP-E2E + DESIGN.md §4):
//!
//! 1. characterise the simulated platform (π, β — paper §2.1–2.2);
//! 2. validate the measurement methodology (§2.3–2.4);
//! 3. reproduce every figure (Fig 3–8 + appendix) and write `reports/`;
//! 4. print a paper-vs-measured summary for the headline numbers;
//! 5. if AOT artifacts exist, run the real Pallas-kernel CNN through
//!    PJRT to prove the three layers compose.
//!
//! ```sh
//! make artifacts && cargo run --release --example full_paper_repro
//! ```

use std::path::Path;

use dlroofline::coordinator::runner::run_and_write;
use dlroofline::harness::experiments::{experiment_index, ExperimentParams};
use dlroofline::harness::CacheState;
use dlroofline::runtime::{Engine, HostTensor};
use dlroofline::util::human::{fmt_pct, fmt_seconds};

fn main() -> anyhow::Result<()> {
    let params = ExperimentParams::default();
    let out_dir = Path::new("reports");

    println!("=== dlroofline: full paper reproduction ===\n");
    println!("platform: {} (simulated; DESIGN.md §5)\n", params.machine.name);

    // 1–3. Every experiment, written to reports/.
    let mut summaries: Vec<String> = Vec::new();
    for (id, title) in experiment_index() {
        print!("running {id:<4} {title} ... ");
        let t0 = std::time::Instant::now();
        let (result, _) = run_and_write(id, &params, out_dir, true)?;
        println!("ok ({})", fmt_seconds(t0.elapsed().as_secs_f64()));

        // Collect paper-vs-measured rows for the summary.
        for group in &result.groups {
            let points = group.points();
            for e in &group.expectations {
                let Some(paper) = e.utilization else { continue };
                let Some(p) = points.iter().find(|p| {
                    p.name == e.kernel && (p.note == "cold" || p.note.is_empty())
                }) else {
                    continue;
                };
                let measured = p.utilization(&group.roofline);
                summaries.push(format!(
                    "| {} | {} | {} | {} | {:+.1} pp |",
                    id,
                    e.kernel,
                    fmt_pct(paper),
                    fmt_pct(measured),
                    (measured - paper) * 100.0,
                ));
            }
        }
    }

    println!("\n=== paper vs measured (utilisation of peak, cold caches) ===");
    println!("| figure | kernel | paper | measured | Δ |");
    println!("|---|---|---|---|---|");
    for row in &summaries {
        println!("{row}");
    }

    // 5. The real three-layer path.
    println!("\n=== end-to-end PJRT run (L1 Pallas → L2 JAX → L3 rust) ===");
    match Engine::from_default_artifacts() {
        Err(e) => println!("skipped: {e} (run `make artifacts`)"),
        Ok(mut engine) => {
            let kernel = engine.load("cnn_forward")?;
            let spec = kernel.spec.clone();
            let inputs: Vec<HostTensor> = spec
                .inputs
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let mut t = HostTensor::random(&s.shape, 7 + i as u64);
                    t.data.iter_mut().for_each(|v| *v *= 0.1);
                    t
                })
                .collect();
            let stats = kernel.benchmark(&inputs, 2, 10)?;
            println!(
                "cnn_forward on {}: mean {} per batch-{} forward ({} artifacts total)",
                engine.platform(),
                fmt_seconds(stats.time.mean),
                spec.inputs[0].shape[0],
                engine.manifest().artifacts.len(),
            );

            // Cross-check one primitive's numerics against the rust-side
            // reference implementation of GELU.
            let gelu = engine.load("gelu_nchw")?;
            let x = HostTensor::random(&gelu.spec.inputs[0].shape, 99);
            let y = gelu.run(std::slice::from_ref(&x))?.remove(0);
            let want: Vec<f32> = x
                .data
                .iter()
                .map(|&v| {
                    let erf = libm_erf(v as f64 / std::f64::consts::SQRT_2);
                    (0.5 * v as f64 * (1.0 + erf)) as f32
                })
                .collect();
            let max_err = y
                .data
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            anyhow::ensure!(max_err < 1e-4, "GELU numerics drifted: {max_err}");
            println!("gelu_nchw numerics vs rust reference: max |Δ| = {max_err:.2e} ✓");
        }
    }

    println!("\nreports written to reports/ — each report carries its paper-vs-measured table.");
    let _ = CacheState::Cold; // (documented entry point for readers)
    Ok(())
}

/// Abramowitz–Stegun erf approximation (|err| < 1.5e-7) — good enough to
/// cross-check the artifact numerics without a libm dependency.
fn libm_erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}
