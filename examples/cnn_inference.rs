//! End-to-end driver (EXP-E2E): load the AOT-compiled blocked-layout CNN
//! (conv → GELU → avgpool → layernorm → FC, every layer a Pallas kernel
//! authored in `python/compile/`) through PJRT and serve batched
//! inference requests from Rust, reporting latency and throughput.
//!
//! Python is *not* running here — the artifacts were lowered once by
//! `make artifacts`; this binary is self-contained.
//!
//! ```sh
//! make artifacts && cargo run --release --example cnn_inference
//! ```

use dlroofline::runtime::{Engine, HostTensor};
use dlroofline::util::human::{fmt_flops, fmt_seconds};
use dlroofline::util::stats::Summary;

const REQUESTS: usize = 50;

fn main() -> anyhow::Result<()> {
    let mut engine = match Engine::from_default_artifacts() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", engine.platform());

    let kernel = engine.load("cnn_forward")?;
    let spec = kernel.spec.clone();
    println!(
        "model: {} — {} inputs, {} per forward",
        spec.name,
        spec.inputs.len(),
        dlroofline::util::human::fmt_si(spec.flops, "FLOP")
    );
    let batch = spec.inputs[0].shape[0];

    // Fixed parameters (weights), fresh activations per request.
    let params: Vec<HostTensor> = spec.inputs[1..]
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let mut t = HostTensor::random(&s.shape, 1000 + i as u64);
            // keep magnitudes sane for a random-weight forward pass
            t.data.iter_mut().for_each(|v| *v *= 0.1);
            t
        })
        .collect();

    // Warm the executable.
    {
        let mut inputs = vec![HostTensor::random(&spec.inputs[0].shape, 0)];
        inputs.extend(params.iter().cloned());
        let out = kernel.run(&inputs)?;
        anyhow::ensure!(out[0].shape == spec.outputs[0].shape, "bad output shape");
        anyhow::ensure!(
            out[0].data.iter().all(|x| x.is_finite()),
            "non-finite logits"
        );
    }

    // Serve a stream of batched requests.
    let mut latencies = Vec::with_capacity(REQUESTS);
    let t0 = std::time::Instant::now();
    for req in 0..REQUESTS {
        let mut inputs = vec![HostTensor::random(&spec.inputs[0].shape, req as u64)];
        inputs.extend(params.iter().cloned());
        let start = std::time::Instant::now();
        let out = kernel.run(&inputs)?;
        latencies.push(start.elapsed().as_secs_f64());
        std::hint::black_box(&out[0].data);
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = Summary::of(&latencies);

    println!("\nserved {REQUESTS} requests (batch {batch}):");
    println!(
        "  latency  mean {} | p50 {} | p95 {} | max {}",
        fmt_seconds(s.mean),
        fmt_seconds(s.median),
        fmt_seconds(s.p95),
        fmt_seconds(s.max)
    );
    println!(
        "  throughput {:.1} samples/s | {}",
        REQUESTS as f64 * batch as f64 / wall,
        fmt_flops(spec.flops / s.mean)
    );
    println!(
        "  (interpret-mode Pallas lowers to scalarised HLO; the number to \
         watch is the three-layer composition, not absolute FLOP/s — see \
         benches/e2e_pipeline.rs)"
    );
    Ok(())
}
