//! Quickstart: build a roofline model for the simulated Xeon 6248 and
//! place one kernel on it — the 30-second tour of the public API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dlroofline::harness::{measure_kernel, CacheState, ScenarioSpec};
use dlroofline::kernels::inner_product::InnerProduct;
use dlroofline::roofline::model::RooflineModel;
use dlroofline::roofline::plot::ascii_plot;
use dlroofline::roofline::report::markdown_table;
use dlroofline::sim::machine::{Machine, MachineConfig};

fn main() -> anyhow::Result<()> {
    // 1. A platform: the paper's 2-socket Xeon Gold 6248 (DESIGN.md §5).
    let config = MachineConfig::xeon_6248();
    let mut machine = Machine::new(config.clone());

    // 2. A kernel: the paper's Fig 6 inner product (fits the LLC).
    let kernel = InnerProduct::paper_shape();

    // 3. Measure W (PMU model), Q (cache sim → IMC) and R (timing model)
    //    under the single-thread scenario, cold and warm.
    let st = ScenarioSpec::single_thread();
    let cold = measure_kernel(&mut machine, &kernel, &st, CacheState::Cold)?;
    let warm = measure_kernel(&mut machine, &kernel, &st, CacheState::Warm)?;

    // 4. The roofline for that scenario, with both points.
    let roofline = RooflineModel::for_machine(&config, 1, 1, "single-thread");
    let points = vec![cold.point(), warm.point()];
    print!("{}", markdown_table(&roofline, &points));
    println!("{}", ascii_plot(&roofline, &points));

    println!(
        "warm-cache arithmetic intensity is {:.1}x the cold-cache one — \
         same Work, far less Traffic (paper §3.2).",
        points[1].ai() / points[0].ai()
    );
    Ok(())
}
