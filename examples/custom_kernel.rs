//! Extending the library: define your own `KernelModel`, register it, and
//! get a full roofline measurement — the downstream-user workflow.
//!
//! The kernel here is an AXPY (`y = a*x + y`): one FMA per element,
//! streaming two arrays — a textbook memory-bound kernel whose point
//! should land on the diagonal part of the roof.
//!
//! ```sh
//! cargo run --release --example custom_kernel
//! ```

use dlroofline::coordinator::KernelRegistry;
use dlroofline::harness::{measure_kernel, CacheState, ScenarioSpec};
use dlroofline::kernels::{KernelModel, TensorMap};
use dlroofline::roofline::model::RooflineModel;
use dlroofline::roofline::plot::ascii_plot;
use dlroofline::roofline::report::markdown_table;
use dlroofline::sim::core::{InstrMix, VecWidth};
use dlroofline::sim::machine::{AddressSpace, Machine, MachineConfig};
use dlroofline::sim::numa::MemPolicy;
use dlroofline::sim::trace::{AccessKind, AccessRun, Trace};

/// `y[i] = a * x[i] + y[i]` over `n` f32 elements.
#[derive(Clone, Debug)]
struct Axpy {
    n: usize,
}

impl KernelModel for Axpy {
    fn name(&self) -> String {
        "axpy".into()
    }

    fn description(&self) -> String {
        format!("y = a*x + y over {} f32", self.n)
    }

    fn alloc(&self, space: &mut AddressSpace, policy: MemPolicy, nodes: usize) -> TensorMap {
        let bytes = self.n as u64 * 4;
        let mut t = TensorMap::default();
        t.insert("x", space.alloc("x", bytes, policy, nodes), bytes);
        t.insert("y", space.alloc("y", bytes, policy, nodes), bytes);
        t
    }

    fn instr_mix(&self) -> InstrMix {
        let vecs = self.n as f64 / 16.0;
        InstrMix {
            fma: vecs,        // one vfmadd per vector
            load: vecs * 2.0, // x and y
            store: vecs,      // y
            alu: vecs * 0.1,
            width: VecWidth::V512,
            ilp: 1.0,
            ..Default::default()
        }
    }

    fn traces(&self, t: &TensorMap, threads: usize) -> Vec<Trace> {
        let bytes = self.n as u64 * 4;
        (0..threads)
            .map(|i| {
                let lo = bytes * i as u64 / threads as u64;
                let hi = bytes * (i as u64 + 1) / threads as u64;
                let mut tr = Trace::new();
                if hi > lo {
                    tr.push(AccessRun::contiguous(t.base("x") + lo, hi - lo, AccessKind::Load));
                    tr.push(AccessRun::contiguous(t.base("y") + lo, hi - lo, AccessKind::Load));
                    tr.push(AccessRun::contiguous(t.base("y") + lo, hi - lo, AccessKind::Store));
                }
                tr
            })
            .collect()
    }
}

fn main() -> anyhow::Result<()> {
    // Optional: make it available to the CLI-style registry too.
    let mut registry = KernelRegistry::with_builtins();
    registry.register("axpy", |scale| Box::new(Axpy { n: scale.max(1) << 20 }));

    let config = MachineConfig::xeon_6248();
    let kernel = registry.create("axpy", 16)?; // 16 Mi elements = 64 MiB/array

    let mut points = Vec::new();
    for scenario in [ScenarioSpec::single_thread(), ScenarioSpec::one_socket()] {
        let mut machine = Machine::new(config.clone());
        let m = measure_kernel(&mut machine, kernel.as_ref(), &scenario, CacheState::Cold)?;
        points.push(m.point().with_note(scenario.label()));
    }

    let roofline = RooflineModel::for_machine(&config, 20, 1, "one-socket");
    print!("{}", markdown_table(&roofline, &points));
    println!("{}", ascii_plot(&roofline, &points));
    println!(
        "AXPY's AI is fixed (~1 FMA / 12 streamed bytes); adding threads \
         slides the point up the same diagonal until the socket bandwidth \
         roof — the canonical memory-bound story."
    );
    Ok(())
}
