//! Bench: regenerate the paper's Fig4 convolution one socket figure.
//! Workload, kernels and expected numbers: DESIGN.md §4 (EXP-F4).

#[path = "common.rs"]
mod common;

fn main() {
    common::figure_bench("f4");
}
