//! Bench: regenerate the paper's Fig4 convolution one socket figure.
//! Workload, kernels and expectations resolve through the spec registry
//! (`harness::spec::registry()`, DESIGN.md §4, EXP-F4) — nothing is
//! duplicated here.

#[path = "common.rs"]
mod common;

fn main() {
    common::figure_bench("f4");
}
