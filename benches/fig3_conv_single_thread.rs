//! Bench: regenerate the paper's Fig3 convolution single thread figure.
//! Workload, kernels and expectations resolve through the spec registry
//! (`harness::spec::registry()`, DESIGN.md §4, EXP-F3) — nothing is
//! duplicated here.

#[path = "common.rs"]
mod common;

fn main() {
    common::figure_bench("f3");
}
