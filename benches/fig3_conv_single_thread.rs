//! Bench: regenerate the paper's Fig3 convolution single thread figure.
//! Workload, kernels and expected numbers: DESIGN.md §4 (EXP-F3).

#[path = "common.rs"]
mod common;

fn main() {
    common::figure_bench("f3");
}
