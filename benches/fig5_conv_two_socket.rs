//! Bench: regenerate the paper's Fig5 convolution two sockets figure.
//! Workload, kernels and expected numbers: DESIGN.md §4 (EXP-F5).

#[path = "common.rs"]
mod common;

fn main() {
    common::figure_bench("f5");
}
