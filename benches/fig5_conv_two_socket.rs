//! Bench: regenerate the paper's Fig5 convolution two sockets figure.
//! Workload, kernels and expectations resolve through the spec registry
//! (`harness::spec::registry()`, DESIGN.md §4, EXP-F5) — nothing is
//! duplicated here.

#[path = "common.rs"]
mod common;

fn main() {
    common::figure_bench("f5");
}
