//! Bench: claim-coordinated store fill (`serve/worker.rs`) versus the
//! plain plan executor.
//!
//! Three configurations over the same multi-figure plan:
//!
//! * `direct`    — PR 4's plan executor, no store, the baseline cost of
//!   simulating every unique cell;
//! * `fill_cold` — `fill_store_sharded` into a fresh cache every
//!   iteration: the same simulations plus claim-file coordination and
//!   record write-back — the overhead a sweep-service worker pays;
//! * `fill_warm` — `fill_store_sharded` over a populated cache: every
//!   cell is a store hit, measuring the pure claim + lookup path a
//!   second daemon sharing the cache dir would follow.
//!
//! The cold/direct gap is the price of crash-safe worker sharding; the
//! warm row is why it amortizes. Writes `BENCH_serve_shard.json` at the
//! repo root so the trajectory is machine-readable across PRs.

use std::time::Duration;

use dlroofline::benchkit::{Bencher, Throughput};
use dlroofline::coordinator::plan::{self, JobBudget};
use dlroofline::coordinator::store::CellStore;
use dlroofline::harness::experiments::ExperimentParams;
use dlroofline::serve::{fill_store_sharded, ClaimSet, ShardProgress};
use dlroofline::testutil::TempDir;

fn main() {
    let params = ExperimentParams { batch: Some(1), ..Default::default() };
    let ids = ["f3", "f6"];
    let expansion = plan::expand(&ids, &params).expect("plan expands");
    let unique = expansion.unique_cells().len();
    let budget = JobBudget { jobs: 2, sim_jobs: 1 };
    let ttl = Duration::from_secs(600);

    let mut b = Bencher::new("serve_shard");

    b.bench("direct", Throughput::Elements(unique as f64), || {
        plan::execute(&ids, &params, 2, true).expect("sweep").stats.cells_simulated
    });

    b.bench("fill_cold", Throughput::Elements(unique as f64), || {
        let dir = TempDir::new("bench-fill-cold");
        let store = CellStore::open(dir.path()).expect("open store");
        let claims = ClaimSet::new(store.root(), ttl);
        let progress = ShardProgress::new(unique);
        let stats = fill_store_sharded(&store, &expansion, &params, budget, &claims, &progress)
            .expect("cold fill");
        assert_eq!(stats.simulated, unique);
        stats.simulated
    });

    let dir = TempDir::new("bench-fill-warm");
    let store = CellStore::open(dir.path()).expect("open store");
    {
        let claims = ClaimSet::new(store.root(), ttl);
        let progress = ShardProgress::new(unique);
        fill_store_sharded(&store, &expansion, &params, budget, &claims, &progress)
            .expect("populate");
    }
    b.bench("fill_warm", Throughput::Elements(unique as f64), || {
        let claims = ClaimSet::new(store.root(), ttl);
        let progress = ShardProgress::new(unique);
        let stats = fill_store_sharded(&store, &expansion, &params, budget, &claims, &progress)
            .expect("warm fill");
        assert_eq!(stats.simulated, 0);
        stats.hits
    });

    b.finish();
    let path = b.emit_json().expect("write bench JSON");
    println!("wrote {}", path.display());
}
