//! Shared bench scaffolding: run a paper experiment, print the same rows
//! the paper reports (per-kernel utilisation + paper-vs-measured), and
//! time the full measurement pipeline with `benchkit`.

use dlroofline::benchkit::{Bencher, Throughput};
use dlroofline::coordinator::runner::render_report;
use dlroofline::harness::experiments::{run_experiment, ExperimentParams};

/// Default params for benches: modest batch so a full `cargo bench`
/// stays in minutes; honour DLROOFLINE_BENCH_FULL=1 for paper sizes.
pub fn bench_params() -> ExperimentParams {
    ExperimentParams {
        full_size: std::env::var("DLROOFLINE_BENCH_FULL").as_deref() == Ok("1"),
        ..Default::default()
    }
}

/// Run one figure experiment: print its report (the paper's rows) and
/// benchmark the simulation pipeline end-to-end.
pub fn figure_bench(id: &str) {
    let params = bench_params();

    // The scientific output: the figure itself.
    let result = run_experiment(id, &params).expect("experiment");
    print!("{}", render_report(&result));

    // The engineering output: how fast the pipeline regenerates it.
    let mut b = Bencher::new(&format!("pipeline/{id}"));
    let flops: f64 = result
        .groups
        .iter()
        .flat_map(|g| g.measurements.iter())
        .map(|m| m.measured.work_flops as f64)
        .sum();
    b.bench(&format!("regenerate_{id}"), Throughput::Flops(flops.max(1.0)), || {
        run_experiment(id, &params).expect("experiment rerun")
    });
    b.finish();
}

#[allow(dead_code)]
fn main() {}
