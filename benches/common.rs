//! Shared bench scaffolding: resolve a figure in the declarative spec
//! registry, print the same rows the paper reports (per-kernel
//! utilisation + paper-vs-measured), and time the full measurement
//! pipeline with `benchkit`.
//!
//! Each `fig*.rs` bench is a one-line registry lookup — experiment ids,
//! kernels, scenarios and params all come from
//! `dlroofline::harness::spec::registry()`, never from the bench itself.

use dlroofline::benchkit::{Bencher, Throughput};
use dlroofline::coordinator::runner::render_report;
use dlroofline::harness::experiments::ExperimentParams;
use dlroofline::harness::spec;

/// Default params for benches: modest batch so a full `cargo bench`
/// stays in minutes; honour DLROOFLINE_BENCH_FULL=1 for paper sizes.
pub fn bench_params() -> ExperimentParams {
    ExperimentParams {
        full_size: std::env::var("DLROOFLINE_BENCH_FULL").as_deref() == Ok("1"),
        ..Default::default()
    }
}

/// Run one registry experiment: print its report (the paper's rows) and
/// benchmark the simulation pipeline end-to-end.
pub fn figure_bench(id: &str) {
    let spec = spec::find(id).expect("experiment id in spec registry");
    let params = bench_params();

    // The scientific output: the figure itself.
    let result = spec.run(&params).expect("experiment");
    print!("{}", render_report(&result));

    // The engineering output: how fast the pipeline regenerates it.
    let mut b = Bencher::new(&format!("pipeline/{}", spec.id));
    let flops: f64 = result
        .groups
        .iter()
        .flat_map(|g| g.measurements.iter())
        .map(|m| m.measured.work_flops as f64)
        .sum();
    b.bench(
        &format!("regenerate_{}", spec.id),
        Throughput::Flops(flops.max(1.0)),
        || spec.run(&params).expect("experiment rerun"),
    );
    b.finish();
}

#[allow(dead_code)]
fn main() {}
