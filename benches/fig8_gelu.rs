//! Bench: regenerate the paper's Fig8 GELU forced blocked figure.
//! Workload, kernels and expected numbers: DESIGN.md §4 (EXP-F8).

#[path = "common.rs"]
mod common;

fn main() {
    common::figure_bench("f8");
}
