//! Bench: regenerate the paper's Fig8 GELU forced blocked figure.
//! Workload, kernels and expectations resolve through the spec registry
//! (`harness::spec::registry()`, DESIGN.md §4, EXP-F8) — nothing is
//! duplicated here.

#[path = "common.rs"]
mod common;

fn main() {
    common::figure_bench("f8");
}
