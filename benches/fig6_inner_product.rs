//! Bench: regenerate the paper's Fig6 inner product figure.
//! Workload, kernels and expected numbers: DESIGN.md §4 (EXP-F6).

#[path = "common.rs"]
mod common;

fn main() {
    common::figure_bench("f6");
}
