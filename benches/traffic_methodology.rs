//! Bench: the §2.4 traffic-methodology ladder (EXP-V2) — LLC-miss
//! counting vs IMC counting, with the hardware prefetcher on/off and the
//! software-prefetching Winograd GEMM that defeats everything except the
//! IMC counters.

#[path = "common.rs"]
mod common;

fn main() {
    common::figure_bench("v2");
}
