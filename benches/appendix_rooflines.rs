//! Bench: regenerate every appendix roofline (layer norm, GELU with
//! favourable dims, inner product and pooling at socket/two-socket
//! scale) — EXP-A1..A4, resolved through the spec registry (DESIGN.md §4).

#[path = "common.rs"]
mod common;

fn main() {
    for id in ["a1", "a2", "a3", "a4"] {
        common::figure_bench(id);
    }
}
