//! Bench: platform characterisation — the paper's §2.1 (peak compute,
//! Fig 2 technique) and §2.2 (peak bandwidth) tables, plus the §2.3 FMA
//! counting validation (EXP-P1, EXP-P2, EXP-V1).
//!
//! Two halves:
//!   * the simulated Xeon 6248 tables (what every figure's roofline uses);
//!   * the REAL host microbenchmarks (runtime-JIT FMA streams and
//!     memset/memcpy/NT-store bandwidth) — the §2.1/§2.2 programs run on
//!     the machine executing this bench.

#[path = "common.rs"]
mod common;

use dlroofline::benchkit::{Bencher, Throughput};
use dlroofline::hostbench::{membw, peak_flops, CpuInfo, MemBwMethod, PeakIsa};

fn main() {
    common::figure_bench("p1");
    common::figure_bench("p2");
    common::figure_bench("v1");

    // --- the real thing, on this host ---------------------------------
    let info = CpuInfo::detect();
    println!(
        "host: {} ({} cpus, {} node(s))",
        info.model_name, info.logical_cpus, info.numa_nodes
    );
    let mut b = Bencher::new("hostbench");
    let secs = 0.3;

    for isa in [PeakIsa::Scalar, PeakIsa::Avx2Fma, PeakIsa::Avx512Fma] {
        if isa == PeakIsa::Avx512Fma && !info.has_avx512f {
            continue;
        }
        let r = peak_flops::measure(isa, &[], 1, secs).expect("peak");
        b.record(
            &format!("peak/{}{}", isa.label(), if r.jitted { "+jit" } else { "" }),
            Throughput::Flops(r.flops_per_sec * secs),
            &[secs],
        );
    }

    let buffer = 64 * 1024 * 1024;
    for method in MemBwMethod::all() {
        let r = membw::measure(method, &[], 1, buffer, secs).expect("membw");
        b.record(
            &format!("membw/{}", method.label()),
            Throughput::Bytes(r.bytes_per_sec * secs),
            &[secs],
        );
    }
    b.finish();
}
