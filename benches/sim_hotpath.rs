//! Perf-pass bench: the simulator's hot loop in isolation — line probes
//! per second through the full L1/L2/LLC/prefetch/IMC stack, for the
//! access patterns that dominate the figures (streaming, strided,
//! LLC-resident rescans, 20-thread interleaving).
//!
//! ROADMAP.md's simulator hot-path item tracks this number across
//! optimisation steps; the run also writes `BENCH_sim_hotpath.json` at
//! the repo root so the trajectory is machine-readable across PRs.
//!
//! The `*_scalar_ref` series time the retained pre-batching walk
//! (`MemorySystem::run_reference`, per-line probes + `dyn` dispatch) on
//! the two ISSUE-target cases, so a single run records the speedup of
//! the SoA/batch/monomorphization pass (§Perf step 6) as the ratio to
//! the matching batched series.
//!
//! The `*_twophase{1,2,8}` series time the two-phase parallel engine
//! (`MemorySystem::run_parallel`, §Perf step 7) at 1/2/8 phase-A
//! workers on the same cases: the 20-thread series against the serial
//! pipeline is the ISSUE-5 target ratio (≥ 1.5× at 8 workers on a
//! multi-core host); the single-thread stream documents the engine's
//! overhead floor (phase A clamps to one worker there).
//!
//! The `*_sharded{1,2,8}` series time the set-sharded engine
//! (`MemorySystem::run_sharded`, §Perf step 8) at N workers × N set
//! shards. `threads20_8MiB_each_sharded8` against the serial pipeline
//! is the ISSUE-9 target ratio (≥ 3× at 8 workers on a multi-core
//! host); `twosocket_llc_heavy*` is the phase-B-bound shape the engine
//! exists for — private-level-defeating strides on two NUMA nodes, so
//! nearly every probe survives into the shared-level replay. Each
//! sharded series also records its `*_phase_a` / `*_phase_b` wall-time
//! split (time-only entries from `last_phase_split`), pinning *where*
//! the time goes, not just the total.

use dlroofline::benchkit::{Bencher, Throughput};
use dlroofline::sim::hierarchy::{HierarchyConfig, MemorySystem};
use dlroofline::sim::numa::Placement;
use dlroofline::sim::trace::{AccessKind, AccessRun, Trace};

fn streaming_trace(mb: u64) -> Trace {
    let mut t = Trace::new();
    t.push(AccessRun::contiguous(0, mb << 20, AccessKind::Load));
    t
}

fn strided_trace(lines: u64, stride: i64) -> Trace {
    let mut t = Trace::new();
    t.push(AccessRun { base: 0, stride, count: lines, size: 4, kind: AccessKind::Load });
    t
}

fn twenty_thread_traces() -> Vec<Trace> {
    (0..20)
        .map(|i| {
            let mut t = Trace::new();
            t.push(AccessRun::contiguous((i as u64) << 26, 8 << 20, AccessKind::Load));
            t
        })
        .collect()
}

/// Eight threads, four per NUMA node, each walking a page-strided
/// 256 MiB window: every probe misses the private levels and the
/// prefetcher never engages, so nearly the whole stream survives into
/// the shared-level replay — phase B dominates wall-time, which is the
/// regime set sharding targets.
fn twosocket_llc_heavy_traces() -> Vec<Trace> {
    (0..8)
        .map(|i| {
            let mut t = Trace::new();
            t.push(AccessRun {
                base: (i as u64) << 28,
                stride: 4096,
                count: 1 << 16,
                size: 4,
                kind: AccessKind::Load,
            });
            t
        })
        .collect()
}

fn main() {
    let cfg = HierarchyConfig::xeon_6248();
    let mut b = Bencher::new("sim_hotpath");

    // 64 MiB cold stream = 1 Mi line probes.
    {
        let tr = streaming_trace(64);
        let probes = tr.line_probes() as f64;
        let mut ms = MemorySystem::new(cfg, 2, 1);
        b.bench("stream_64MiB_cold", Throughput::Elements(probes), || {
            ms.flush_all();
            ms.run_with(std::slice::from_ref(&tr), &Placement::bound(1, 0), |_a, _t| 0)
                .probes
        });
        b.bench("stream_64MiB_cold_scalar_ref", Throughput::Elements(probes), || {
            ms.flush_all();
            ms.run_reference(std::slice::from_ref(&tr), &Placement::bound(1, 0), &mut |_a, _t| 0)
                .probes
        });
        for workers in [1usize, 2, 8] {
            let name = format!("stream_64MiB_cold_twophase{workers}");
            b.bench(&name, Throughput::Elements(probes), || {
                ms.flush_all();
                ms.run_parallel(
                    std::slice::from_ref(&tr),
                    &Placement::bound(1, 0),
                    |_a, _t| 0,
                    workers,
                )
                .probes
            });
        }
    }

    // LLC-resident rescan (all hits below LLC): 16 MiB x2.
    {
        let tr = streaming_trace(16);
        let probes = tr.line_probes() as f64;
        let mut ms = MemorySystem::new(cfg, 2, 1);
        ms.run_with(std::slice::from_ref(&tr), &Placement::bound(1, 0), |_a, _t| 0);
        b.bench("rescan_16MiB_warm", Throughput::Elements(probes), || {
            ms.run_with(std::slice::from_ref(&tr), &Placement::bound(1, 0), |_a, _t| 0)
                .probes
        });
    }

    // Pathological stride (every line new set, prefetcher useless).
    {
        let tr = strided_trace(1 << 20, 4096);
        let probes = tr.line_probes() as f64;
        let mut ms = MemorySystem::new(cfg, 2, 1);
        b.bench("strided_4k_1Mi", Throughput::Elements(probes), || {
            ms.flush_all();
            ms.run_with(std::slice::from_ref(&tr), &Placement::bound(1, 0), |_a, _t| 0)
                .probes
        });
    }

    // 20-thread interleaved streams (the one-socket figures).
    {
        let traces = twenty_thread_traces();
        let probes: f64 = traces.iter().map(|t| t.line_probes() as f64).sum();
        let mut ms = MemorySystem::new(cfg, 2, 20);
        b.bench("threads20_8MiB_each", Throughput::Elements(probes), || {
            ms.flush_all();
            ms.run_with(&traces, &Placement::bound(20, 0), |_a, _t| 0).probes
        });
        b.bench("threads20_8MiB_each_scalar_ref", Throughput::Elements(probes), || {
            ms.flush_all();
            ms.run_reference(&traces, &Placement::bound(20, 0), &mut |_a, _t| 0)
                .probes
        });
        // The ISSUE-5 A/B series: the big-cell shape the two-phase
        // engine targets (20 private pipelines run concurrently, then
        // one serial shared-level replay).
        for workers in [1usize, 2, 8] {
            let name = format!("threads20_8MiB_each_twophase{workers}");
            b.bench(&name, Throughput::Elements(probes), || {
                ms.flush_all();
                ms.run_parallel(&traces, &Placement::bound(20, 0), |_a, _t| 0, workers)
                    .probes
            });
        }
        // The ISSUE-9 A/B series: set-sharded phase B at N workers ×
        // N shards, with the wall-time split of the last run recorded
        // alongside the end-to-end number.
        for n in [1usize, 2, 8] {
            let name = format!("threads20_8MiB_each_sharded{n}");
            b.bench(&name, Throughput::Elements(probes), || {
                ms.flush_all();
                ms.run_sharded(&traces, &Placement::bound(20, 0), |_a, _t| 0, n, n).probes
            });
            let split = ms.last_phase_split();
            b.record(&format!("{name}_phase_a"), Throughput::None, &[split.phase_a_seconds]);
            b.record(&format!("{name}_phase_b"), Throughput::None, &[split.phase_b_seconds]);
        }
    }

    // Two-socket, shared-level-bound streams (phase B dominates).
    {
        let traces = twosocket_llc_heavy_traces();
        let probes: f64 = traces.iter().map(|t| t.line_probes() as f64).sum();
        let node_of = |addr: u64, _t: usize| ((addr >> 28) & 1) as usize;
        let mut ms = MemorySystem::new(cfg, 2, traces.len());
        b.bench("twosocket_llc_heavy", Throughput::Elements(probes), || {
            ms.flush_all();
            ms.run_with(&traces, &Placement::spread(8, 2), node_of).probes
        });
        for n in [1usize, 2, 8] {
            let name = format!("twosocket_llc_heavy_sharded{n}");
            b.bench(&name, Throughput::Elements(probes), || {
                ms.flush_all();
                ms.run_sharded(&traces, &Placement::spread(8, 2), node_of, n, n).probes
            });
            let split = ms.last_phase_split();
            b.record(&format!("{name}_phase_a"), Throughput::None, &[split.phase_a_seconds]);
            b.record(&format!("{name}_phase_b"), Throughput::None, &[split.phase_b_seconds]);
        }
    }

    b.finish();
    let path = b.emit_json().expect("write bench JSON");
    println!("wrote {}", path.display());
}
