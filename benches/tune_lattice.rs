//! Bench: tuning-lattice search through the persistent cell cache
//! (`tune/` on top of `coordinator/store.rs`).
//!
//! Three configurations over the same two-family lattice:
//!
//! * `cold` — fresh cache directory every iteration: simulate every
//!   variant and pay the record write-back (the first-ever tune);
//! * `warm` — pre-populated cache: a re-tune of an unchanged lattice,
//!   zero simulations, pure lookup + ranking — the steady state;
//! * `one_edit` — fresh cache, populate with the base lattice, then run
//!   the edited lattice (one extra block factor): the edit's cost is
//!   bounded by the added variants, not the lattice size. This case
//!   times base-populate + edit together; its delta over `cold` is the
//!   memoization saving the tuning workflow promises.
//!
//! Writes `BENCH_tune_lattice.json` at the repo root so the trajectory
//! is machine-readable across PRs (bench-smoke uploads it).

use dlroofline::benchkit::{Bencher, Throughput};
use dlroofline::coordinator::plan::JobBudget;
use dlroofline::coordinator::store::CellStore;
use dlroofline::harness::experiments::ExperimentParams;
use dlroofline::harness::{CacheState, ScenarioSpec};
use dlroofline::kernels::{DataLayout, LoopOrder, TuneKernel};
use dlroofline::testutil::TempDir;
use dlroofline::tune::{self, TuningLattice};

fn lattice(blocks: Vec<usize>) -> TuningLattice {
    TuningLattice {
        kernels: vec![TuneKernel::ConvDirect, TuneKernel::InnerProduct],
        scenarios: vec![ScenarioSpec::single_thread(), ScenarioSpec::one_socket()],
        cache: CacheState::Cold,
        layouts: vec![DataLayout::Nchw, DataLayout::Nchw16c],
        blocks,
        orders: vec![LoopOrder::IcInner],
        prefetch: vec![0],
    }
}

fn main() {
    let params = ExperimentParams { batch: Some(1), ..Default::default() };
    let base = lattice(vec![8]);
    let edited = lattice(vec![8, 4]);
    let cells = edited.to_spec().cells().len() as f64;
    let budget = JobBudget::cells(0);

    let mut b = Bencher::new("tune_lattice");

    b.bench("cold", Throughput::Elements(cells), || {
        let dir = TempDir::new("bench-tune-cold");
        let store = CellStore::open(dir.path()).expect("open store");
        let report = tune::run(&edited, &params, budget, Some(&store)).expect("cold tune");
        assert_eq!(report.store.as_ref().map(|u| u.hits), Some(0));
        report.stats.cells_simulated
    });

    let dir = TempDir::new("bench-tune-warm");
    let store = CellStore::open(dir.path()).expect("open store");
    tune::run(&edited, &params, budget, Some(&store)).expect("populate");
    b.bench("warm", Throughput::Elements(cells), || {
        let report = tune::run(&edited, &params, budget, Some(&store)).expect("warm tune");
        assert_eq!(report.store.as_ref().map(|u| u.simulated), Some(0));
        report.store.map(|u| u.hits)
    });

    b.bench("one_edit", Throughput::Elements(cells), || {
        let dir = TempDir::new("bench-tune-edit");
        let store = CellStore::open(dir.path()).expect("open store");
        let first = tune::run(&base, &params, budget, Some(&store)).expect("base tune");
        let report = tune::run(&edited, &params, budget, Some(&store)).expect("edited tune");
        let usage = report.store.as_ref().expect("store usage");
        assert_eq!(usage.hits, first.stats.cells_simulated);
        assert_eq!(usage.simulated, report.stats.cells_simulated - first.stats.cells_simulated);
        usage.simulated
    });

    b.finish();
    let path = b.emit_json().expect("write bench JSON");
    println!("wrote {}", path.display());
}
