//! Bench: regenerate the paper's Fig7 average pooling figure.
//! Workload, kernels and expected numbers: DESIGN.md §4 (EXP-F7).

#[path = "common.rs"]
mod common;

fn main() {
    common::figure_bench("f7");
}
