//! Bench: incremental sweeps through the persistent cell cache
//! (`coordinator/store.rs`).
//!
//! Three configurations over the same multi-figure plan:
//!
//! * `no_store`   — PR 1's in-process memoization only (the baseline);
//! * `cold_store` — fresh cache directory every iteration: simulate
//!   everything *and* pay the record write-back;
//! * `warm_store` — pre-populated cache: zero simulations, pure
//!   lookup + assembly — the steady state every repeated machine-grid
//!   or parameter sweep reaches after its first run.
//!
//! The warm/no-store ratio is the amortization the ROADMAP item
//! promised: repeated sweeps cost disk reads, not simulations. The run
//! also writes `BENCH_sweep_incremental.json` at the repo root so the
//! trajectory is machine-readable across PRs.

use dlroofline::benchkit::{Bencher, Throughput};
use dlroofline::coordinator::plan;
use dlroofline::coordinator::store::CellStore;
use dlroofline::harness::experiments::ExperimentParams;
use dlroofline::testutil::TempDir;

fn main() {
    let params = ExperimentParams { batch: Some(1), ..Default::default() };
    let ids = ["f3", "f4", "f5", "f6", "f7", "g1"];
    let cells = plan::expand(&ids, &params).expect("plan expands").stats.cells_total as f64;

    let mut b = Bencher::new("sweep_incremental");

    b.bench("no_store", Throughput::Elements(cells), || {
        plan::execute(&ids, &params, 0, true).expect("sweep").stats.cells_simulated
    });

    b.bench("cold_store", Throughput::Elements(cells), || {
        let dir = TempDir::new("bench-cold");
        let store = CellStore::open(dir.path()).expect("open store");
        let out = plan::execute_with_store(&ids, &params, 0, true, Some(&store))
            .expect("cold sweep");
        assert_eq!(out.store.as_ref().map(|u| u.hits), Some(0));
        out.stats.cells_simulated
    });

    let dir = TempDir::new("bench-warm");
    let store = CellStore::open(dir.path()).expect("open store");
    plan::execute_with_store(&ids, &params, 0, true, Some(&store)).expect("populate");
    b.bench("warm_store", Throughput::Elements(cells), || {
        let out = plan::execute_with_store(&ids, &params, 0, true, Some(&store))
            .expect("warm sweep");
        assert_eq!(out.store.as_ref().map(|u| u.simulated), Some(0));
        out.store.map(|u| u.hits)
    });

    b.finish();
    let path = b.emit_json().expect("write bench JSON");
    println!("wrote {}", path.display());
}
