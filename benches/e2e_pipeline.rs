//! Bench: the end-to-end three-layer pipeline (EXP-E2E).
//!
//! * simulated: full Fig-3 regeneration wallclock (characterise →
//!   measure → roofline), the repo's own "serving" hot path;
//! * real: the AOT-compiled Pallas CNN executed through PJRT from rust,
//!   batched-inference latency/throughput (skipped with a notice when
//!   `make artifacts` has not run).

#[path = "common.rs"]
mod common;

use dlroofline::benchkit::{Bencher, Throughput};
use dlroofline::runtime::{Engine, HostTensor};

fn main() {
    common::figure_bench("f3");

    match Engine::from_default_artifacts() {
        Err(e) => println!("PJRT half skipped: {e}"),
        Ok(mut engine) => {
            let mut b = Bencher::new("e2e/pjrt");
            for name in ["gelu_nchw", "inner_product", "conv_nchw16c", "cnn_forward"] {
                let kernel = match engine.load(name) {
                    Ok(k) => k,
                    Err(e) => {
                        println!("  {name}: {e}");
                        continue;
                    }
                };
                let inputs: Vec<HostTensor> = kernel
                    .spec
                    .inputs
                    .iter()
                    .enumerate()
                    .map(|(i, s)| {
                        let mut t = HostTensor::random(&s.shape, i as u64);
                        t.data.iter_mut().for_each(|v| *v *= 0.1);
                        t
                    })
                    .collect();
                let stats = kernel.benchmark(&inputs, 2, 15).expect("pjrt bench");
                let flops = stats.flops;
                b.record(name, Throughput::Flops(flops), &[stats.time.mean]);
            }
            b.finish();
        }
    }
}
